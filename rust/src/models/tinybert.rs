//! Tiny BERT-style transformer encoder — the NLP stand-in for Table 4
//! (SQuAD F1 / MNLI accuracy under W4A4).
//!
//! Architecture: token embedding + learned positions, `L` encoder blocks
//! (single-head attention → residual → LayerNorm → GELU FFN → residual →
//! LayerNorm), then either a CLS classification head (entailment) or a
//! start/end span head (span extraction). Manual forward/backward, like
//! the CNN stack. Quantization replaces the linear weights with series
//! expansions; LayerNorm/softmax stay FP (the paper's practice — first
//! and last layers 8-bit).

use crate::tensor::{matmul, matmul_a_bt, matmul_at_b, Rng, Tensor};
use crate::xint::layer::LayerPolicy;
use crate::xint::SeriesExpansion;

/// LayerNorm over the last dimension of an (N, D) tensor.
#[derive(Clone, Debug)]
pub struct LayerNorm {
    pub gamma: Tensor,
    pub beta: Tensor,
    pub ggamma: Tensor,
    pub gbeta: Tensor,
    eps: f32,
    cache: Option<(Tensor, Vec<f32>)>, // xhat, inv_std per row
}

impl LayerNorm {
    pub fn new(d: usize) -> Self {
        LayerNorm {
            gamma: Tensor::full(&[d], 1.0),
            beta: Tensor::zeros(&[d]),
            ggamma: Tensor::zeros(&[d]),
            gbeta: Tensor::zeros(&[d]),
            eps: 1e-5,
            cache: None,
        }
    }

    pub fn forward(&self, x: &Tensor) -> Tensor {
        let (n, d) = (x.dims()[0], x.dims()[1]);
        let mut out = Tensor::zeros(&[n, d]);
        for i in 0..n {
            let row = x.row(i);
            let m: f32 = row.iter().sum::<f32>() / d as f32;
            let v: f32 = row.iter().map(|&a| (a - m) * (a - m)).sum::<f32>() / d as f32;
            let inv = 1.0 / (v + self.eps).sqrt();
            for j in 0..d {
                out.data_mut()[i * d + j] =
                    (row[j] - m) * inv * self.gamma.data()[j] + self.beta.data()[j];
            }
        }
        out
    }

    pub fn forward_train(&mut self, x: &Tensor) -> Tensor {
        let (n, d) = (x.dims()[0], x.dims()[1]);
        let mut out = Tensor::zeros(&[n, d]);
        let mut xhat = Tensor::zeros(&[n, d]);
        let mut invs = vec![0.0f32; n];
        for i in 0..n {
            let row = x.row(i);
            let m: f32 = row.iter().sum::<f32>() / d as f32;
            let v: f32 = row.iter().map(|&a| (a - m) * (a - m)).sum::<f32>() / d as f32;
            let inv = 1.0 / (v + self.eps).sqrt();
            invs[i] = inv;
            for j in 0..d {
                let h = (row[j] - m) * inv;
                xhat.data_mut()[i * d + j] = h;
                out.data_mut()[i * d + j] = h * self.gamma.data()[j] + self.beta.data()[j];
            }
        }
        self.cache = Some((xhat, invs));
        out
    }

    pub fn backward(&mut self, dy: &Tensor) -> Tensor {
        let (xhat, invs) = self.cache.as_ref().expect("forward_train first");
        let (n, d) = (dy.dims()[0], dy.dims()[1]);
        let mut dx = Tensor::zeros(&[n, d]);
        for i in 0..n {
            let mut dg_sum = 0.0f32;
            let mut db_sum = 0.0f32;
            for j in 0..d {
                let g = dy.at(&[i, j]);
                self.ggamma.data_mut()[j] += g * xhat.at(&[i, j]);
                self.gbeta.data_mut()[j] += g;
                let gh = g * self.gamma.data()[j];
                dg_sum += gh;
                db_sum += gh * xhat.at(&[i, j]);
            }
            let inv = invs[i];
            for j in 0..d {
                let gh = dy.at(&[i, j]) * self.gamma.data()[j];
                dx.data_mut()[i * d + j] =
                    inv / d as f32 * (d as f32 * gh - dg_sum - xhat.at(&[i, j]) * db_sum);
            }
        }
        dx
    }

    pub fn zero_grad(&mut self) {
        self.ggamma.map_inplace(|_| 0.0);
        self.gbeta.map_inplace(|_| 0.0);
    }
}

/// One single-head encoder block with pre-allocated grads.
#[derive(Clone, Debug)]
pub struct EncoderBlock {
    pub wq: Tensor,
    pub wk: Tensor,
    pub wv: Tensor,
    pub wo: Tensor,
    pub w1: Tensor,
    pub w2: Tensor,
    pub ln1: LayerNorm,
    pub ln2: LayerNorm,
    pub gq: Tensor,
    pub gk: Tensor,
    pub gv: Tensor,
    pub go: Tensor,
    pub g1: Tensor,
    pub g2: Tensor,
    cache: Option<BlockCache>,
}

#[derive(Clone, Debug)]
struct BlockCache {
    x: Tensor,        // (N·T, D) block input
    q: Tensor,
    k: Tensor,
    v: Tensor,
    attn: Vec<Tensor>, // per sequence (T,T) softmax
    ctx: Tensor,       // (N·T, D) attention context
    ff_in: Tensor,     // LN1 output
    ff_mid_pre: Tensor, // pre-GELU
    ff_mid: Tensor,    // post-GELU
}

impl EncoderBlock {
    pub fn new(d: usize, ff: usize, rng: &mut Rng) -> Self {
        let std = (1.0 / d as f32).sqrt();
        let g = |dims: &[usize]| Tensor::zeros(dims);
        EncoderBlock {
            wq: Tensor::randn(&[d, d], std, rng),
            wk: Tensor::randn(&[d, d], std, rng),
            wv: Tensor::randn(&[d, d], std, rng),
            wo: Tensor::randn(&[d, d], std, rng),
            w1: Tensor::randn(&[ff, d], std, rng),
            w2: Tensor::randn(&[d, ff], (1.0 / ff as f32).sqrt(), rng),
            ln1: LayerNorm::new(d),
            ln2: LayerNorm::new(d),
            gq: g(&[d, d]),
            gk: g(&[d, d]),
            gv: g(&[d, d]),
            go: g(&[d, d]),
            g1: g(&[ff, d]),
            g2: g(&[d, ff]),
            cache: None,
        }
    }

    /// Forward with optionally quantized weights (PTQ swaps the matmuls).
    fn attn_forward(
        x: &Tensor,
        wq: &Tensor,
        wk: &Tensor,
        wv: &Tensor,
        wo: &Tensor,
        n: usize,
        t: usize,
        causal: bool,
    ) -> (Tensor, Tensor, Tensor, Vec<Tensor>, (Tensor, Tensor)) {
        let d = x.dims()[1];
        let q = matmul_a_bt(x, wq);
        let k = matmul_a_bt(x, wk);
        let v = matmul_a_bt(x, wv);
        let scale = 1.0 / (d as f32).sqrt();
        let mut ctx = Tensor::zeros(&[n * t, d]);
        let mut attns = Vec::with_capacity(n);
        for s in 0..n {
            let qs = Tensor::from_vec(&[t, d], q.data()[s * t * d..(s + 1) * t * d].to_vec());
            let ks = Tensor::from_vec(&[t, d], k.data()[s * t * d..(s + 1) * t * d].to_vec());
            let vs = Tensor::from_vec(&[t, d], v.data()[s * t * d..(s + 1) * t * d].to_vec());
            let mut scores = matmul_a_bt(&qs, &ks).scale(scale);
            if causal {
                for i in 0..t {
                    for j in (i + 1)..t {
                        scores.data_mut()[i * t + j] = -1e9;
                    }
                }
            }
            let a = scores.softmax_rows();
            let c = matmul(&a, &vs);
            ctx.data_mut()[s * t * d..(s + 1) * t * d].copy_from_slice(c.data());
            attns.push(a);
        }
        let out = matmul_a_bt(&ctx, wo);
        (q, k, v, attns, (out, ctx))
    }

    pub fn forward(&self, x: &Tensor, n: usize, t: usize, causal: bool) -> Tensor {
        let (_q, _k, _v, _a, (attn_out, _ctx)) =
            Self::attn_forward(x, &self.wq, &self.wk, &self.wv, &self.wo, n, t, causal);
        let h1 = self.ln1.forward(&x.add(&attn_out));
        let mid = matmul_a_bt(&h1, &self.w1).gelu();
        let ff = matmul_a_bt(&mid, &self.w2);
        self.ln2.forward(&h1.add(&ff))
    }

    pub fn forward_train(&mut self, x: &Tensor, n: usize, t: usize, causal: bool) -> Tensor {
        let (q, k, v, attns, (attn_out, ctx)) =
            Self::attn_forward(x, &self.wq, &self.wk, &self.wv, &self.wo, n, t, causal);
        let res1 = x.add(&attn_out);
        let ff_in = self.ln1.forward_train(&res1);
        let ff_mid_pre = matmul_a_bt(&ff_in, &self.w1);
        let ff_mid = ff_mid_pre.gelu();
        let ff = matmul_a_bt(&ff_mid, &self.w2);
        let h2 = ff_in.add(&ff);
        let out = self.ln2.forward_train(&h2);
        self.cache = Some(BlockCache {
            x: x.clone(),
            q,
            k,
            v,
            attn: attns,
            ctx,
            ff_in,
            ff_mid_pre,
            ff_mid,
        });
        out
    }

    pub fn backward(&mut self, dy: &Tensor, n: usize, t: usize, causal: bool) -> Tensor {
        let cache = self.cache.take().expect("forward_train first");
        let d = cache.x.dims()[1];
        // LN2
        let dh2 = self.ln2.backward(dy);
        // h2 = ff_in + ff
        let dff = dh2.clone();
        // ff = ff_mid × w2ᵀ
        self.g2.axpy(1.0, &matmul_at_b(&dff, &cache.ff_mid));
        let dff_mid = matmul(&dff, &self.w2);
        // gelu
        let dff_mid_pre = dff_mid.zip(&cache.ff_mid_pre, |g, v| g * crate::tensor::gelu_grad(v));
        // ff_mid_pre = ff_in × w1ᵀ
        self.g1.axpy(1.0, &matmul_at_b(&dff_mid_pre, &cache.ff_in));
        let dff_in = matmul(&dff_mid_pre, &self.w1).add(&dh2); // + residual
        // LN1
        let dres1 = self.ln1.backward(&dff_in);
        // res1 = x + attn_out ⇒ dx gets dres1, attn_out gets dres1
        let dattn_out = dres1.clone();
        // attn_out = ctx × woᵀ
        self.go.axpy(1.0, &matmul_at_b(&dattn_out, &cache.ctx));
        let dctx = matmul(&dattn_out, &self.wo);
        // per-sequence attention backward
        let scale = 1.0 / (d as f32).sqrt();
        let mut dq = Tensor::zeros(&[n * t, d]);
        let mut dk = Tensor::zeros(&[n * t, d]);
        let mut dv = Tensor::zeros(&[n * t, d]);
        for s in 0..n {
            let slice = |t2: &Tensor| {
                Tensor::from_vec(&[t, d], t2.data()[s * t * d..(s + 1) * t * d].to_vec())
            };
            let qs = slice(&cache.q);
            let ks = slice(&cache.k);
            let vs = slice(&cache.v);
            let dctxs = slice(&dctx);
            let a = &cache.attn[s];
            // ctx = a × v
            let da = matmul_a_bt(&dctxs, &vs); // (t,t): dctx × vᵀ
            let dvs = matmul_at_b(a, &dctxs); // aᵀ × dctx
            // softmax backward per row: ds = a ⊙ (da − Σ a⊙da)
            let mut dscores = Tensor::zeros(&[t, t]);
            for i in 0..t {
                let arow = a.row(i);
                let darow = da.row(i);
                let dot: f32 = arow.iter().zip(darow).map(|(x, y)| x * y).sum();
                for j in 0..t {
                    let v = arow[j] * (darow[j] - dot);
                    dscores.data_mut()[i * t + j] =
                        if causal && j > i { 0.0 } else { v };
                }
            }
            let dscores = dscores.scale(scale);
            // scores = q × kᵀ
            let dqs = matmul(&dscores, &ks);
            let dks = matmul_at_b(&dscores, &qs);
            dq.data_mut()[s * t * d..(s + 1) * t * d].copy_from_slice(dqs.data());
            dk.data_mut()[s * t * d..(s + 1) * t * d].copy_from_slice(dks.data());
            dv.data_mut()[s * t * d..(s + 1) * t * d].copy_from_slice(dvs.data());
        }
        // q = x × wqᵀ etc.
        self.gq.axpy(1.0, &matmul_at_b(&dq, &cache.x));
        self.gk.axpy(1.0, &matmul_at_b(&dk, &cache.x));
        self.gv.axpy(1.0, &matmul_at_b(&dv, &cache.x));
        let dx_attn = matmul(&dq, &self.wq)
            .add(&matmul(&dk, &self.wk))
            .add(&matmul(&dv, &self.wv));
        dres1.add(&dx_attn)
    }

    pub fn zero_grad(&mut self) {
        for g in [&mut self.gq, &mut self.gk, &mut self.gv, &mut self.go, &mut self.g1, &mut self.g2]
        {
            g.map_inplace(|_| 0.0);
        }
        self.ln1.zero_grad();
        self.ln2.zero_grad();
    }

    pub fn visit_params(&mut self, f: &mut dyn FnMut(&mut Tensor, &Tensor)) {
        // Destructuring splits the borrow per field, so each (param,
        // grad) pair can be lent out disjointly — no raw pointers, no
        // per-visit gradient clones.
        let EncoderBlock { wq, wk, wv, wo, w1, w2, ln1, ln2, gq, gk, gv, go, g1, g2, cache: _ } =
            self;
        f(wq, gq);
        f(wk, gk);
        f(wv, gv);
        f(wo, go);
        f(w1, g1);
        f(w2, g2);
        for ln in [ln1, ln2] {
            let LayerNorm { gamma, beta, ggamma, gbeta, .. } = ln;
            f(gamma, ggamma);
            f(beta, gbeta);
        }
    }

    /// Replace each weight matrix by its series-expanded reconstruction
    /// under `policy` (the PTQ transform for transformers: the quantized
    /// multiplication is exactly the expanded one because the GEMM error
    /// *is* the reconstruction error — see DESIGN.md §6).
    pub fn quantize_weights(&mut self, policy: &LayerPolicy) {
        let cfg = policy.weight_config();
        for w in [&mut self.wq, &mut self.wk, &mut self.wv, &mut self.wo, &mut self.w1, &mut self.w2]
        {
            let e = SeriesExpansion::expand(w, &cfg);
            *w = e.reconstruct();
        }
    }

    pub fn params(&self) -> usize {
        self.wq.numel() + self.wk.numel() + self.wv.numel() + self.wo.numel()
            + self.w1.numel()
            + self.w2.numel()
            + self.ln1.gamma.numel() * 2
            + self.ln2.gamma.numel() * 2
    }
}

/// Output heads.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BertHead {
    /// classify from the CLS (first) token
    Cls { classes: usize },
    /// start/end span logits per token
    Span,
}

/// The tiny BERT model.
#[derive(Clone, Debug)]
pub struct TinyBert {
    pub vocab: usize,
    pub d: usize,
    pub seq: usize,
    pub embed: Tensor,     // (vocab, d)
    pub pos: Tensor,       // (seq, d)
    pub blocks: Vec<EncoderBlock>,
    pub head: BertHead,
    pub w_head: Tensor, // (classes, d) or (2, d)
    pub gembed: Tensor,
    pub gpos: Tensor,
    pub ghead: Tensor,
    cache_tokens: Option<Vec<Vec<usize>>>,
    /// inference-time activation quantization: (bits, expansion terms)
    pub act_quant: Option<(u32, usize)>,
    cache_feat: Option<Tensor>,
}

impl TinyBert {
    pub fn new(vocab: usize, d: usize, ff: usize, layers: usize, seq: usize, head: BertHead, seed: u64) -> Self {
        let mut rng = Rng::seed(seed);
        let hdim = match head {
            BertHead::Cls { classes } => classes,
            BertHead::Span => 2,
        };
        TinyBert {
            vocab,
            d,
            seq,
            embed: Tensor::randn(&[vocab, d], 0.5, &mut rng),
            pos: Tensor::randn(&[seq, d], 0.1, &mut rng),
            blocks: (0..layers).map(|_| EncoderBlock::new(d, ff, &mut rng)).collect(),
            head,
            w_head: Tensor::randn(&[hdim, d], (1.0 / d as f32).sqrt(), &mut rng),
            gembed: Tensor::zeros(&[vocab, d]),
            gpos: Tensor::zeros(&[seq, d]),
            ghead: Tensor::zeros(&[hdim, d]),
            act_quant: None,
            cache_tokens: None,
            cache_feat: None,
        }
    }

    fn embed_batch(&self, tokens: &[Vec<usize>]) -> Tensor {
        let n = tokens.len();
        let mut x = Tensor::zeros(&[n * self.seq, self.d]);
        for (s, seq) in tokens.iter().enumerate() {
            assert_eq!(seq.len(), self.seq);
            for (p, &tok) in seq.iter().enumerate() {
                let dst = (s * self.seq + p) * self.d;
                for j in 0..self.d {
                    x.data_mut()[dst + j] =
                        self.embed.data()[tok * self.d + j] + self.pos.data()[p * self.d + j];
                }
            }
        }
        x
    }

    /// Features (N·T, D) after all blocks. When `act_quant = Some((bits,
    /// terms))`, hidden states between blocks are series-expanded and
    /// reconstructed at that precision — the W·A· quantized inference
    /// mode (terms=1 is plain fake quantization; terms>1 is Eq. 4).
    pub fn features(&self, tokens: &[Vec<usize>]) -> Tensor {
        let n = tokens.len();
        let mut h = self.embed_batch(tokens);
        for b in &self.blocks {
            if let Some((bits, terms)) = self.act_quant {
                let cfg = crate::xint::ExpandConfig::activations(
                    crate::xint::BitSpec::int(bits),
                    terms,
                );
                h = SeriesExpansion::expand(&h, &cfg).reconstruct();
            }
            h = b.forward(&h, n, self.seq, false);
        }
        h
    }

    /// Inference logits: (N, classes) for CLS head, (N, T, 2)→(N·T, 2) for span.
    pub fn forward(&self, tokens: &[Vec<usize>]) -> Tensor {
        let n = tokens.len();
        let h = self.features(tokens);
        match self.head {
            BertHead::Cls { .. } => {
                // take CLS rows
                let mut cls = Tensor::zeros(&[n, self.d]);
                for s in 0..n {
                    let src = s * self.seq * self.d;
                    cls.data_mut()[s * self.d..(s + 1) * self.d]
                        .copy_from_slice(&h.data()[src..src + self.d]);
                }
                matmul_a_bt(&cls, &self.w_head)
            }
            BertHead::Span => matmul_a_bt(&h, &self.w_head),
        }
    }

    pub fn forward_train(&mut self, tokens: &[Vec<usize>]) -> Tensor {
        let n = tokens.len();
        let mut h = self.embed_batch(tokens);
        for b in &mut self.blocks {
            h = b.forward_train(&h, n, self.seq, false);
        }
        self.cache_tokens = Some(tokens.to_vec());
        self.cache_feat = Some(h.clone());
        match self.head {
            BertHead::Cls { .. } => {
                let mut cls = Tensor::zeros(&[n, self.d]);
                for s in 0..n {
                    let src = s * self.seq * self.d;
                    cls.data_mut()[s * self.d..(s + 1) * self.d]
                        .copy_from_slice(&h.data()[src..src + self.d]);
                }
                matmul_a_bt(&cls, &self.w_head)
            }
            BertHead::Span => matmul_a_bt(&h, &self.w_head),
        }
    }

    pub fn backward(&mut self, dlogits: &Tensor) {
        let tokens = self.cache_tokens.take().expect("forward_train first");
        let feat = self.cache_feat.take().expect("forward_train first");
        let n = tokens.len();
        let mut dfeat = Tensor::zeros(&[n * self.seq, self.d]);
        match self.head {
            BertHead::Cls { .. } => {
                // dlogits (N, C); head input = CLS rows of feat
                let mut cls = Tensor::zeros(&[n, self.d]);
                for s in 0..n {
                    let src = s * self.seq * self.d;
                    cls.data_mut()[s * self.d..(s + 1) * self.d]
                        .copy_from_slice(&feat.data()[src..src + self.d]);
                }
                self.ghead.axpy(1.0, &matmul_at_b(dlogits, &cls));
                let dcls = matmul(dlogits, &self.w_head);
                for s in 0..n {
                    let dst = s * self.seq * self.d;
                    dfeat.data_mut()[dst..dst + self.d]
                        .copy_from_slice(&dcls.data()[s * self.d..(s + 1) * self.d]);
                }
            }
            BertHead::Span => {
                self.ghead.axpy(1.0, &matmul_at_b(dlogits, &feat));
                dfeat = matmul(dlogits, &self.w_head);
            }
        }
        let mut g = dfeat;
        for b in self.blocks.iter_mut().rev() {
            g = b.backward(&g, n, self.seq, false);
        }
        // embedding grads
        for (s, seq) in tokens.iter().enumerate() {
            for (p, &tok) in seq.iter().enumerate() {
                let src = (s * self.seq + p) * self.d;
                for j in 0..self.d {
                    self.gembed.data_mut()[tok * self.d + j] += g.data()[src + j];
                    self.gpos.data_mut()[p * self.d + j] += g.data()[src + j];
                }
            }
        }
    }

    pub fn zero_grad(&mut self) {
        self.gembed.map_inplace(|_| 0.0);
        self.gpos.map_inplace(|_| 0.0);
        self.ghead.map_inplace(|_| 0.0);
        for b in &mut self.blocks {
            b.zero_grad();
        }
    }

    pub fn visit_params(&mut self, f: &mut dyn FnMut(&mut Tensor, &Tensor)) {
        f(&mut self.embed, &self.gembed.clone());
        f(&mut self.pos, &self.gpos.clone());
        f(&mut self.w_head, &self.ghead.clone());
        for b in &mut self.blocks {
            b.visit_params(f);
        }
    }

    pub fn params(&self) -> usize {
        self.embed.numel()
            + self.pos.numel()
            + self.w_head.numel()
            + self.blocks.iter().map(|b| b.params()).sum::<usize>()
    }

    /// PTQ: series-expand every interior block weight; embedding and head
    /// follow the paper's 8-bit first/last rule.
    pub fn quantize(&mut self, policy: &LayerPolicy) {
        let eight = LayerPolicy::eight_bit();
        let e_cfg = eight.weight_config();
        let e = SeriesExpansion::expand(&self.embed, &e_cfg);
        self.embed = e.reconstruct();
        for b in &mut self.blocks {
            b.quantize_weights(policy);
        }
        let h = SeriesExpansion::expand(&self.w_head, &e_cfg);
        self.w_head = h.reconstruct();
    }
}

/// Quantize only a *clone* — the harness compares FP vs quantized.
pub fn quantized_copy(model: &TinyBert, policy: &LayerPolicy) -> TinyBert {
    let mut m = model.clone();
    m.quantize(policy);
    m
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy_tokens(n: usize, seq: usize, seed: u64) -> Vec<Vec<usize>> {
        let mut rng = Rng::seed(seed);
        (0..n).map(|_| (0..seq).map(|_| rng.below(16)).collect()).collect()
    }

    #[test]
    fn forward_shapes_cls_and_span() {
        let cls = TinyBert::new(16, 8, 16, 2, 6, BertHead::Cls { classes: 3 }, 1);
        let toks = toy_tokens(4, 6, 2);
        assert_eq!(cls.forward(&toks).dims(), &[4, 3]);
        let span = TinyBert::new(16, 8, 16, 1, 6, BertHead::Span, 1);
        assert_eq!(span.forward(&toks).dims(), &[24, 2]);
    }

    #[test]
    fn train_step_reduces_loss() {
        let mut m = TinyBert::new(16, 8, 16, 1, 6, BertHead::Cls { classes: 2 }, 3);
        let toks = toy_tokens(8, 6, 4);
        let labels: Vec<usize> = (0..8).map(|i| i % 2).collect();
        let loss_of = |m: &mut TinyBert| {
            let logits = m.forward_train(&toks);
            let ls = logits.log_softmax_rows();
            -labels.iter().enumerate().map(|(i, &y)| ls.at(&[i, y])).sum::<f32>() / 8.0
        };
        let l0 = loss_of(&mut m);
        for _ in 0..30 {
            m.zero_grad();
            let logits = m.forward_train(&toks);
            let sm = logits.softmax_rows();
            let mut dl = sm.clone();
            for (i, &y) in labels.iter().enumerate() {
                dl.data_mut()[i * 2 + y] -= 1.0;
            }
            let dl = dl.scale(1.0 / 8.0);
            m.backward(&dl);
            m.visit_params(&mut |p, g| p.axpy(-0.5, g));
        }
        let l1 = loss_of(&mut m);
        assert!(l1 < l0 * 0.7, "loss {l0} -> {l1}");
    }

    #[test]
    fn block_backward_matches_fd_spot() {
        let mut rng = Rng::seed(5);
        let mut b = EncoderBlock::new(4, 8, &mut rng);
        let x = Tensor::randn(&[6, 4], 1.0, &mut rng); // n=2, t=3
        b.zero_grad();
        let y = b.forward_train(&x, 2, 3, false);
        let _dx = b.backward(&y, 2, 3, false); // loss = Σy²/2
        let loss = |b: &EncoderBlock, x: &Tensor| {
            let y = b.forward(x, 2, 3, false);
            y.data().iter().map(|&v| 0.5 * v * v).sum::<f32>()
        };
        let eps = 1e-2;
        for &i in &[0usize, 5, 11] {
            let mut bp = b.clone();
            bp.wq.data_mut()[i] += eps;
            let mut bm = b.clone();
            bm.wq.data_mut()[i] -= eps;
            let fd = (loss(&bp, &x) - loss(&bm, &x)) / (2.0 * eps);
            let got = b.gq.data()[i];
            assert!((fd - got).abs() < 0.05 * (1.0 + fd.abs()), "wq[{i}] fd {fd} vs {got}");
        }
        for &i in &[0usize, 13] {
            let mut bp = b.clone();
            bp.w1.data_mut()[i] += eps;
            let mut bm = b.clone();
            bm.w1.data_mut()[i] -= eps;
            let fd = (loss(&bp, &x) - loss(&bm, &x)) / (2.0 * eps);
            let got = b.g1.data()[i];
            assert!((fd - got).abs() < 0.05 * (1.0 + fd.abs()), "w1[{i}] fd {fd} vs {got}");
        }
    }

    #[test]
    fn causal_mask_blocks_future() {
        let mut rng = Rng::seed(7);
        let b = EncoderBlock::new(4, 8, &mut rng);
        let x1 = Tensor::randn(&[4, 4], 1.0, &mut rng); // n=1, t=4
        let mut x2 = x1.clone();
        // perturb the last position only
        for j in 0..4 {
            x2.data_mut()[3 * 4 + j] += 1.0;
        }
        let y1 = b.forward(&x1, 1, 4, true);
        let y2 = b.forward(&x2, 1, 4, true);
        // earlier positions must be unaffected through attention...
        // (LN/FFN are per-position so they preserve this)
        for p in 0..3 {
            for j in 0..4 {
                assert!(
                    (y1.at(&[p, j]) - y2.at(&[p, j])).abs() < 1e-5,
                    "position {p} leaked future info"
                );
            }
        }
    }

    #[test]
    fn quantization_w8_keeps_outputs_w2_single_term_degrades() {
        let m = TinyBert::new(16, 8, 16, 2, 6, BertHead::Cls { classes: 3 }, 9);
        let toks = toy_tokens(4, 6, 10);
        let fp = m.forward(&toks);
        let q8 = quantized_copy(&m, &LayerPolicy::new(8, 8).with_terms(2, 1));
        let e8 = fp.sub(&q8.forward(&toks)).norm() / fp.norm();
        let q2 = quantized_copy(&m, &LayerPolicy::new(2, 2).with_terms(1, 1));
        let e2 = fp.sub(&q2.forward(&toks)).norm() / fp.norm();
        assert!(e8 < 0.05, "W8 err {e8}");
        assert!(e2 > e8, "W2 {e2} should exceed W8 {e8}");
    }
}
