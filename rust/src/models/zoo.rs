//! The model zoo — architecture stand-ins for the paper's Table 1 suite
//! (DESIGN.md §2). Every constructor is deterministic given a seed.
//!
//! | Paper model     | Zoo stand-in        | Shared property              |
//! |-----------------|---------------------|------------------------------|
//! | ResNet-18       | `mini_resnet_a`     | residual conv+BN blocks      |
//! | ResNet-34       | `mini_resnet_b`     | deeper residual stack        |
//! | ResNet-50       | `mini_resnet_c`     | deeper + wider, projections  |
//! | ResNet-101      | `mini_resnet_d`     | deepest stack                |
//! | RegNetX-600MF   | `regnet_style`      | grouped convolutions         |
//! | Inception-V3    | `inception_style`   | multi-branch concat blocks   |
//! | MobileNetV2     | `mobilenet_style`   | depthwise-separable convs    |

use super::graph::{Layer, Model};
use super::layers::{BatchNorm, ConvLayer, LinearLayer};
use crate::tensor::{Conv2dSpec, Rng};

fn conv_bn_relu(inc: usize, outc: usize, k: usize, stride: usize, rng: &mut Rng) -> Vec<Layer> {
    let pad = k / 2;
    vec![
        Layer::Conv(ConvLayer::new(Conv2dSpec::new(inc, outc, k, stride, pad), false, rng)),
        Layer::Bn(BatchNorm::new(outc)),
        Layer::ReLU,
    ]
}

/// A basic residual block (two 3×3 convs; projection shortcut on shape change).
fn res_block(inc: usize, outc: usize, stride: usize, rng: &mut Rng) -> Layer {
    let main = vec![
        Layer::Conv(ConvLayer::new(Conv2dSpec::new(inc, outc, 3, stride, 1), false, rng)),
        Layer::Bn(BatchNorm::new(outc)),
        Layer::ReLU,
        Layer::Conv(ConvLayer::new(Conv2dSpec::new(outc, outc, 3, 1, 1), false, rng)),
        Layer::Bn(BatchNorm::new(outc)),
    ];
    let short = if inc != outc || stride != 1 {
        vec![
            Layer::Conv(ConvLayer::new(Conv2dSpec::new(inc, outc, 1, stride, 0), false, rng)),
            Layer::Bn(BatchNorm::new(outc)),
        ]
    } else {
        vec![]
    };
    Layer::Residual(main, short)
}

fn resnet(name: &str, widths: &[usize], blocks_per_stage: &[usize], classes: usize, seed: u64) -> Model {
    let mut rng = Rng::seed(seed);
    let mut layers = conv_bn_relu(1, widths[0], 3, 1, &mut rng);
    let mut inc = widths[0];
    for (si, (&w, &nb)) in widths.iter().zip(blocks_per_stage).enumerate() {
        for bi in 0..nb {
            let stride = if si > 0 && bi == 0 { 2 } else { 1 };
            layers.push(res_block(inc, w, stride, &mut rng));
            layers.push(Layer::ReLU);
            inc = w;
        }
    }
    layers.push(Layer::GlobalAvgPool);
    layers.push(Layer::Linear(LinearLayer::new(inc, classes, true, &mut rng)));
    Model::new(name, layers)
}

/// ResNet-18 stand-in: 2 stages × 1 block, widths 8/16.
pub fn mini_resnet_a(classes: usize, seed: u64) -> Model {
    resnet("MiniResNet-A", &[8, 16], &[1, 1], classes, seed)
}

/// ResNet-34 stand-in: 2 stages × 2 blocks.
pub fn mini_resnet_b(classes: usize, seed: u64) -> Model {
    resnet("MiniResNet-B", &[8, 16], &[2, 2], classes, seed)
}

/// ResNet-50 stand-in: 3 stages, wider.
pub fn mini_resnet_c(classes: usize, seed: u64) -> Model {
    resnet("MiniResNet-C", &[12, 24, 48], &[2, 2, 2], classes, seed)
}

/// ResNet-101 stand-in: deepest stack.
pub fn mini_resnet_d(classes: usize, seed: u64) -> Model {
    resnet("MiniResNet-D", &[12, 24, 48], &[3, 3, 3], classes, seed)
}

/// RegNetX stand-in: grouped 3×3 convs in the residual trunk.
pub fn regnet_style(classes: usize, seed: u64) -> Model {
    let mut rng = Rng::seed(seed);
    let mut layers = conv_bn_relu(1, 8, 3, 1, &mut rng);
    for (inc, outc, stride) in [(8usize, 16usize, 1usize), (16, 16, 2), (16, 32, 2)] {
        let groups = 4;
        let main = vec![
            Layer::Conv(ConvLayer::new(Conv2dSpec::new(inc, outc, 1, 1, 0), false, &mut rng)),
            Layer::Bn(BatchNorm::new(outc)),
            Layer::ReLU,
            Layer::Conv(ConvLayer::new(
                Conv2dSpec::new(outc, outc, 3, stride, 1).grouped(groups),
                false,
                &mut rng,
            )),
            Layer::Bn(BatchNorm::new(outc)),
            Layer::ReLU,
            Layer::Conv(ConvLayer::new(Conv2dSpec::new(outc, outc, 1, 1, 0), false, &mut rng)),
            Layer::Bn(BatchNorm::new(outc)),
        ];
        let short = if inc != outc || stride != 1 {
            vec![
                Layer::Conv(ConvLayer::new(Conv2dSpec::new(inc, outc, 1, stride, 0), false, &mut rng)),
                Layer::Bn(BatchNorm::new(outc)),
            ]
        } else {
            vec![]
        };
        layers.push(Layer::Residual(main, short));
        layers.push(Layer::ReLU);
    }
    layers.push(Layer::GlobalAvgPool);
    layers.push(Layer::Linear(LinearLayer::new(32, classes, true, &mut rng)));
    Model::new("RegNet-style", layers)
}

/// Inception-V3 stand-in: multi-branch concat blocks (1×1 / 3×3 / 5×5-ish).
pub fn inception_style(classes: usize, seed: u64) -> Model {
    let mut rng = Rng::seed(seed);
    let mut layers = conv_bn_relu(1, 8, 3, 1, &mut rng);
    // two inception blocks
    for inc in [8usize, 16] {
        let b1 = conv_bn_relu(inc, 4, 1, 1, &mut rng);
        let b2 = {
            let mut v = conv_bn_relu(inc, 6, 1, 1, &mut rng);
            v.extend(conv_bn_relu(6, 8, 3, 1, &mut rng));
            v
        };
        let b3 = {
            let mut v = conv_bn_relu(inc, 2, 1, 1, &mut rng);
            v.extend(conv_bn_relu(2, 4, 5, 1, &mut rng));
            v
        };
        layers.push(Layer::Branches(vec![b1, b2, b3])); // 4+8+4 = 16 ch
        layers.push(Layer::MaxPool2);
    }
    layers.push(Layer::GlobalAvgPool);
    layers.push(Layer::Linear(LinearLayer::new(16, classes, true, &mut rng)));
    Model::new("Inception-style", layers)
}

/// MobileNetV2 stand-in: inverted residuals with depthwise 3×3 convs —
/// the architecture PTQ papers consistently find hardest to quantize.
pub fn mobilenet_style(classes: usize, seed: u64) -> Model {
    let mut rng = Rng::seed(seed);
    let mut layers = conv_bn_relu(1, 8, 3, 1, &mut rng);
    for (inc, exp, outc, stride) in
        [(8usize, 16usize, 8usize, 1usize), (8, 24, 12, 2), (12, 36, 12, 1)]
    {
        let main = vec![
            // expand 1×1
            Layer::Conv(ConvLayer::new(Conv2dSpec::new(inc, exp, 1, 1, 0), false, &mut rng)),
            Layer::Bn(BatchNorm::new(exp)),
            Layer::ReLU,
            // depthwise 3×3
            Layer::Conv(ConvLayer::new(Conv2dSpec::depthwise(exp, 3, stride, 1), false, &mut rng)),
            Layer::Bn(BatchNorm::new(exp)),
            Layer::ReLU,
            // project 1×1
            Layer::Conv(ConvLayer::new(Conv2dSpec::new(exp, outc, 1, 1, 0), false, &mut rng)),
            Layer::Bn(BatchNorm::new(outc)),
        ];
        if inc == outc && stride == 1 {
            layers.push(Layer::Residual(main, vec![]));
        } else {
            layers.extend(main);
        }
    }
    layers.push(Layer::GlobalAvgPool);
    layers.push(Layer::Linear(LinearLayer::new(12, classes, true, &mut rng)));
    Model::new("MobileNet-style", layers)
}

/// A plain MLP for quickstart / unit tests (flattens NCHW input first).
pub fn mlp(in_dim: usize, hidden: &[usize], classes: usize, seed: u64) -> Model {
    let mut rng = Rng::seed(seed);
    let mut layers = vec![Layer::Flatten];
    let mut d = in_dim;
    for &h in hidden {
        layers.push(Layer::Linear(LinearLayer::new(d, h, true, &mut rng)));
        layers.push(Layer::ReLU);
        d = h;
    }
    layers.push(Layer::Linear(LinearLayer::new(d, classes, true, &mut rng)));
    Model::new("MLP", layers)
}

/// Table-1 row order: the six CNN stand-ins.
pub fn table1_suite(classes: usize, seed: u64) -> Vec<Model> {
    vec![
        mini_resnet_a(classes, seed),
        mini_resnet_b(classes, seed + 1),
        mini_resnet_c(classes, seed + 2),
        mini_resnet_d(classes, seed + 3),
        regnet_style(classes, seed + 4),
        inception_style(classes, seed + 5),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::{Rng, Tensor};

    fn check(m: &Model, classes: usize) {
        let mut rng = Rng::seed(99);
        let x = Tensor::randn(&[2, 1, 16, 16], 1.0, &mut rng);
        let y = m.forward(&x);
        assert_eq!(y.dims(), &[2, classes], "{}", m.name);
        assert!(y.data().iter().all(|v| v.is_finite()), "{}", m.name);
    }

    #[test]
    fn all_zoo_models_forward() {
        for m in table1_suite(10, 1) {
            check(&m, 10);
        }
        check(&mobilenet_style(10, 2), 10);
        check(&mlp(256, &[64], 10, 3), 10);
    }

    #[test]
    fn depth_ordering_by_params() {
        let a = mini_resnet_a(10, 1).params();
        let b = mini_resnet_b(10, 1).params();
        let c = mini_resnet_c(10, 1).params();
        let d = mini_resnet_d(10, 1).params();
        assert!(a < b && b < c && c < d, "{a} {b} {c} {d}");
    }

    #[test]
    fn zoo_models_trainable_one_step() {
        // one backprop step must run and produce finite grads on each arch
        for mut m in
            vec![mini_resnet_a(4, 5), regnet_style(4, 5), inception_style(4, 5), mobilenet_style(4, 5)]
        {
            let mut rng = Rng::seed(7);
            let x = Tensor::randn(&[2, 1, 16, 16], 1.0, &mut rng);
            m.zero_grad();
            let y = m.forward_train(&x);
            let _ = m.backward(&y);
            let name = m.name.clone();
            let mut saw = false;
            m.visit_params(&mut |_, g| {
                saw = true;
                assert!(g.data().iter().all(|v| v.is_finite()), "{name}");
            });
            assert!(saw);
        }
    }
}
