//! Neural-network model substrate: layer definitions with forward and
//! backward passes, a sequential/residual/branch graph, the model zoo
//! (the paper's CNN suite stand-ins), transformer models (BERT / causal
//! LM stand-ins), quantized-graph construction, and weight serialization.

pub mod basis;
pub mod graph;
pub mod layers;
pub mod quantized;
pub mod serialize;
pub mod tinybert;
pub mod tinylm;
pub mod zoo;

pub use basis::{basis_slices, calibrate_slices, forward_reduced};
pub use graph::{Layer, Model};
pub use layers::{BatchNorm, ConvLayer, LinearLayer};
pub use quantized::{quantize_model, ActObserver, QuantModel};
pub use tinybert::TinyBert;
pub use tinylm::TinyLm;
