//! Sequential model graph with residual blocks and inception-style
//! branches — rich enough to express every architecture in the paper's
//! Table 1 suite while keeping manual backprop tractable.

use super::layers::{BatchNorm, ConvLayer, LinearLayer};
use crate::tensor::Tensor;
use crate::xint::quantizer::{fake_quant, Range};
use crate::xint::BitSpec;

/// A graph node.
#[derive(Clone, Debug)]
pub enum Layer {
    Conv(ConvLayer),
    Bn(BatchNorm),
    Linear(LinearLayer),
    ReLU,
    Gelu,
    MaxPool2,
    GlobalAvgPool,
    Flatten,
    /// residual block: `y = main(x) + shortcut(x)` (empty shortcut = identity)
    Residual(Vec<Layer>, Vec<Layer>),
    /// inception-style: run branches in parallel, concat along channels
    Branches(Vec<Vec<Layer>>),
    /// activation fake-quantization (inserted by PTQ baselines)
    ActQuant(Range, BitSpec),
}

/// Per-layer forward cache for backprop.
#[derive(Clone, Debug)]
enum Cache {
    None,
    Relu(Tensor),            // input
    Gelu(Tensor),            // input
    MaxPool(Tensor),         // input
    Gap(Vec<usize>),         // input dims
    Flatten(Vec<usize>),     // input dims
    Residual(Vec<Cache>, Vec<Cache>),
    Branches(Vec<Vec<Cache>>, Vec<usize>), // per-branch caches + out channels
}

/// A named sequential model.
#[derive(Clone, Debug)]
pub struct Model {
    pub name: String,
    pub layers: Vec<Layer>,
    caches: Vec<Cache>,
}

impl Layer {
    pub fn forward(&self, x: &Tensor) -> Tensor {
        match self {
            Layer::Conv(c) => c.forward(x),
            Layer::Bn(b) => b.forward(x),
            Layer::Linear(l) => l.forward(x),
            Layer::ReLU => x.relu(),
            Layer::Gelu => x.gelu(),
            Layer::MaxPool2 => x.maxpool2(),
            Layer::GlobalAvgPool => x.global_avg_pool(),
            Layer::Flatten => {
                let n = x.dims()[0];
                x.reshape(&[n, x.numel() / n])
            }
            Layer::Residual(main, short) => {
                let mut h = x.clone();
                for l in main {
                    h = l.forward(&h);
                }
                let mut s = x.clone();
                for l in short {
                    s = l.forward(&s);
                }
                h.add(&s)
            }
            Layer::Branches(branches) => {
                let outs: Vec<Tensor> = branches
                    .iter()
                    .map(|b| {
                        let mut h = x.clone();
                        for l in b {
                            h = l.forward(&h);
                        }
                        h
                    })
                    .collect();
                concat_channels(&outs)
            }
            Layer::ActQuant(r, spec) => {
                Tensor::from_vec(x.dims(), fake_quant(x.data(), *r, *spec))
            }
        }
    }

    fn forward_train(&mut self, x: &Tensor) -> (Tensor, Cache) {
        match self {
            Layer::Conv(c) => (c.forward_train(x), Cache::None),
            Layer::Bn(b) => (b.forward_train(x), Cache::None),
            Layer::Linear(l) => (l.forward_train(x), Cache::None),
            Layer::ReLU => (x.relu(), Cache::Relu(x.clone())),
            Layer::Gelu => (x.gelu(), Cache::Gelu(x.clone())),
            Layer::MaxPool2 => (x.maxpool2(), Cache::MaxPool(x.clone())),
            Layer::GlobalAvgPool => (x.global_avg_pool(), Cache::Gap(x.dims().to_vec())),
            Layer::Flatten => {
                let n = x.dims()[0];
                (x.reshape(&[n, x.numel() / n]), Cache::Flatten(x.dims().to_vec()))
            }
            Layer::Residual(main, short) => {
                let mut h = x.clone();
                let mut mc = Vec::new();
                for l in main.iter_mut() {
                    let (nh, c) = l.forward_train(&h);
                    h = nh;
                    mc.push(c);
                }
                let mut s = x.clone();
                let mut sc = Vec::new();
                for l in short.iter_mut() {
                    let (ns, c) = l.forward_train(&s);
                    s = ns;
                    sc.push(c);
                }
                (h.add(&s), Cache::Residual(mc, sc))
            }
            Layer::Branches(branches) => {
                let mut outs = Vec::new();
                let mut caches = Vec::new();
                let mut chans = Vec::new();
                for b in branches.iter_mut() {
                    let mut h = x.clone();
                    let mut bc = Vec::new();
                    for l in b.iter_mut() {
                        let (nh, c) = l.forward_train(&h);
                        h = nh;
                        bc.push(c);
                    }
                    chans.push(h.dims()[1]);
                    outs.push(h);
                    caches.push(bc);
                }
                (concat_channels(&outs), Cache::Branches(caches, chans))
            }
            Layer::ActQuant(r, spec) => {
                // straight-through estimator: cache nothing, pass grads
                (Tensor::from_vec(x.dims(), fake_quant(x.data(), *r, *spec)), Cache::None)
            }
        }
    }

    fn backward(&mut self, dy: &Tensor, cache: &Cache) -> Tensor {
        match (self, cache) {
            (Layer::Conv(c), _) => c.backward(dy),
            (Layer::Bn(b), _) => b.backward(dy),
            (Layer::Linear(l), _) => l.backward(dy),
            (Layer::ReLU, Cache::Relu(x)) => {
                dy.zip(x, |g, v| if v > 0.0 { g } else { 0.0 })
            }
            (Layer::Gelu, Cache::Gelu(x)) => {
                dy.zip(x, |g, v| g * crate::tensor::gelu_grad(v))
            }
            (Layer::MaxPool2, Cache::MaxPool(x)) => maxpool2_backward(x, dy),
            (Layer::GlobalAvgPool, Cache::Gap(dims)) => {
                let (n, c, h, w) = (dims[0], dims[1], dims[2], dims[3]);
                let mut dx = Tensor::zeros(dims);
                let inv = 1.0 / (h * w) as f32;
                for ni in 0..n {
                    for ci in 0..c {
                        let g = dy.at(&[ni, ci]) * inv;
                        let base = (ni * c + ci) * h * w;
                        for v in &mut dx.data_mut()[base..base + h * w] {
                            *v = g;
                        }
                    }
                }
                dx
            }
            (Layer::Flatten, Cache::Flatten(dims)) => dy.reshape(dims),
            (Layer::Residual(main, short), Cache::Residual(mc, sc)) => {
                let mut g = dy.clone();
                for (l, c) in main.iter_mut().rev().zip(mc.iter().rev()) {
                    g = l.backward(&g, c);
                }
                let mut gs = dy.clone();
                for (l, c) in short.iter_mut().rev().zip(sc.iter().rev()) {
                    gs = l.backward(&gs, c);
                }
                g.add(&gs)
            }
            (Layer::Branches(branches), Cache::Branches(caches, chans)) => {
                let mut dx: Option<Tensor> = None;
                let mut off = 0;
                for ((b, bc), &ch) in branches.iter_mut().zip(caches).zip(chans) {
                    let dyb = slice_channels(dy, off, ch);
                    off += ch;
                    let mut g = dyb;
                    for (l, c) in b.iter_mut().rev().zip(bc.iter().rev()) {
                        g = l.backward(&g, c);
                    }
                    dx = Some(match dx {
                        Some(acc) => acc.add(&g),
                        None => g,
                    });
                }
                dx.expect("at least one branch")
            }
            (Layer::ActQuant(..), _) => dy.clone(), // straight-through
            (l, c) => panic!("cache mismatch for {l:?} vs {c:?}"),
        }
    }

    /// Parameter count (recursive).
    pub fn params(&self) -> usize {
        match self {
            Layer::Conv(c) => c.params(),
            Layer::Bn(b) => b.params(),
            Layer::Linear(l) => l.params(),
            Layer::Residual(m, s) => {
                m.iter().map(|l| l.params()).sum::<usize>()
                    + s.iter().map(|l| l.params()).sum::<usize>()
            }
            Layer::Branches(bs) => {
                bs.iter().flat_map(|b| b.iter().map(|l| l.params())).sum()
            }
            _ => 0,
        }
    }

    /// Visit every (param, grad) pair.
    pub fn visit_params(&mut self, f: &mut dyn FnMut(&mut Tensor, &Tensor)) {
        match self {
            Layer::Conv(c) => {
                f(&mut c.w, &c.gw.clone());
                if let (Some(b), Some(gb)) = (&mut c.b, &c.gb) {
                    f(b, &gb.clone());
                }
            }
            Layer::Bn(b) => {
                f(&mut b.gamma, &b.ggamma.clone());
                f(&mut b.beta, &b.gbeta.clone());
            }
            Layer::Linear(l) => {
                f(&mut l.w, &l.gw.clone());
                if let (Some(b), Some(gb)) = (&mut l.b, &l.gb) {
                    f(b, &gb.clone());
                }
            }
            Layer::Residual(m, s) => {
                for l in m.iter_mut().chain(s.iter_mut()) {
                    l.visit_params(f);
                }
            }
            Layer::Branches(bs) => {
                for b in bs {
                    for l in b {
                        l.visit_params(f);
                    }
                }
            }
            _ => {}
        }
    }

    /// Zero all gradients.
    pub fn zero_grad(&mut self) {
        match self {
            Layer::Conv(c) => {
                c.gw.map_inplace(|_| 0.0);
                if let Some(gb) = &mut c.gb {
                    gb.map_inplace(|_| 0.0);
                }
            }
            Layer::Bn(b) => {
                b.ggamma.map_inplace(|_| 0.0);
                b.gbeta.map_inplace(|_| 0.0);
            }
            Layer::Linear(l) => {
                l.gw.map_inplace(|_| 0.0);
                if let Some(gb) = &mut l.gb {
                    gb.map_inplace(|_| 0.0);
                }
            }
            Layer::Residual(m, s) => {
                for l in m.iter_mut().chain(s.iter_mut()) {
                    l.zero_grad();
                }
            }
            Layer::Branches(bs) => {
                for b in bs {
                    for l in b {
                        l.zero_grad();
                    }
                }
            }
            _ => {}
        }
    }
}

impl Model {
    pub fn new(name: &str, layers: Vec<Layer>) -> Self {
        Model { name: name.to_string(), layers, caches: Vec::new() }
    }

    /// Inference forward.
    pub fn forward(&self, x: &Tensor) -> Tensor {
        let mut h = x.clone();
        for l in &self.layers {
            h = l.forward(&h);
        }
        h
    }

    /// Training forward (records caches).
    pub fn forward_train(&mut self, x: &Tensor) -> Tensor {
        let mut h = x.clone();
        self.caches.clear();
        for l in &mut self.layers {
            let (nh, c) = l.forward_train(&h);
            h = nh;
            self.caches.push(c);
        }
        h
    }

    /// Backward from output gradient; returns input gradient.
    pub fn backward(&mut self, dy: &Tensor) -> Tensor {
        assert_eq!(self.caches.len(), self.layers.len(), "run forward_train first");
        let mut g = dy.clone();
        for (l, c) in self.layers.iter_mut().rev().zip(self.caches.iter().rev()) {
            g = l.backward(&g, c);
        }
        g
    }

    pub fn zero_grad(&mut self) {
        for l in &mut self.layers {
            l.zero_grad();
        }
    }

    pub fn visit_params(&mut self, f: &mut dyn FnMut(&mut Tensor, &Tensor)) {
        for l in &mut self.layers {
            l.visit_params(f);
        }
    }

    pub fn params(&self) -> usize {
        self.layers.iter().map(|l| l.params()).sum()
    }

    /// Fold every (Conv, Bn) pair — in sequence, inside residual mains,
    /// shortcuts and branches — into the conv; required before PTQ.
    pub fn fold_bn(&mut self) {
        fn fold_seq(layers: &mut Vec<Layer>) {
            let mut i = 0;
            while i < layers.len() {
                // recurse first
                match &mut layers[i] {
                    Layer::Residual(m, s) => {
                        fold_seq(m);
                        fold_seq(s);
                    }
                    Layer::Branches(bs) => {
                        for b in bs {
                            fold_seq(b);
                        }
                    }
                    _ => {}
                }
                if i + 1 < layers.len() {
                    if let (Layer::Conv(_), Layer::Bn(_)) = (&layers[i], &layers[i + 1]) {
                        let Layer::Bn(bn) = layers.remove(i + 1) else { unreachable!() };
                        let Layer::Conv(conv) = &mut layers[i] else { unreachable!() };
                        bn.fold_into(conv);
                    }
                }
                i += 1;
            }
        }
        fold_seq(&mut self.layers);
    }
}

/// Public concat used by the quantized graph (same layout rules).
pub fn concat_channels_pub(xs: &[Tensor]) -> Tensor {
    concat_channels(xs)
}

/// Concatenate NCHW tensors along the channel axis.
fn concat_channels(xs: &[Tensor]) -> Tensor {
    let n = xs[0].dims()[0];
    let (h, w) = (xs[0].dims()[2], xs[0].dims()[3]);
    let total_c: usize = xs.iter().map(|x| x.dims()[1]).sum();
    let mut out = Tensor::zeros(&[n, total_c, h, w]);
    for ni in 0..n {
        let mut off = 0;
        for x in xs {
            let c = x.dims()[1];
            assert_eq!(x.dims()[2], h);
            assert_eq!(x.dims()[3], w);
            let src = &x.data()[ni * c * h * w..(ni + 1) * c * h * w];
            let dst_base = (ni * total_c + off) * h * w;
            out.data_mut()[dst_base..dst_base + c * h * w].copy_from_slice(src);
            off += c;
        }
    }
    out
}

/// Slice `ch` channels starting at `off` from an NCHW tensor.
fn slice_channels(x: &Tensor, off: usize, ch: usize) -> Tensor {
    let (n, c, h, w) = (x.dims()[0], x.dims()[1], x.dims()[2], x.dims()[3]);
    let mut out = Tensor::zeros(&[n, ch, h, w]);
    for ni in 0..n {
        let src = (ni * c + off) * h * w;
        let dst = ni * ch * h * w;
        out.data_mut()[dst..dst + ch * h * w].copy_from_slice(&x.data()[src..src + ch * h * w]);
    }
    out
}

fn maxpool2_backward(x: &Tensor, dy: &Tensor) -> Tensor {
    let (n, c, h, w) = (x.dims()[0], x.dims()[1], x.dims()[2], x.dims()[3]);
    let (oh, ow) = (h / 2, w / 2);
    let mut dx = Tensor::zeros(x.dims());
    for ni in 0..n {
        for ci in 0..c {
            for oi in 0..oh {
                for oj in 0..ow {
                    // find argmax in 2×2 window
                    let mut best = f32::NEG_INFINITY;
                    let mut bi = 0;
                    let mut bj = 0;
                    for di in 0..2 {
                        for dj in 0..2 {
                            let v = x.at(&[ni, ci, oi * 2 + di, oj * 2 + dj]);
                            if v > best {
                                best = v;
                                bi = di;
                                bj = dj;
                            }
                        }
                    }
                    let g = dy.at(&[ni, ci, oi, oj]);
                    let idx = ((ni * c + ci) * h + oi * 2 + bi) * w + oj * 2 + bj;
                    dx.data_mut()[idx] += g;
                }
            }
        }
    }
    dx
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::{Conv2dSpec, Rng};

    fn tiny_cnn(seed: u64) -> Model {
        let mut rng = Rng::seed(seed);
        Model::new(
            "tiny",
            vec![
                Layer::Conv(ConvLayer::new(Conv2dSpec::new(1, 4, 3, 1, 1), false, &mut rng)),
                Layer::Bn(BatchNorm::new(4)),
                Layer::ReLU,
                Layer::Residual(
                    vec![
                        Layer::Conv(ConvLayer::new(Conv2dSpec::new(4, 4, 3, 1, 1), false, &mut rng)),
                        Layer::Bn(BatchNorm::new(4)),
                        Layer::ReLU,
                    ],
                    vec![],
                ),
                Layer::MaxPool2,
                Layer::GlobalAvgPool,
                Layer::Linear(LinearLayer::new(4, 3, true, &mut rng)),
            ],
        )
    }

    #[test]
    fn forward_shapes() {
        let m = tiny_cnn(1);
        let mut rng = Rng::seed(2);
        let x = Tensor::randn(&[2, 1, 8, 8], 1.0, &mut rng);
        let y = m.forward(&x);
        assert_eq!(y.dims(), &[2, 3]);
        assert!(m.params() > 0);
    }

    #[test]
    fn whole_model_gradient_matches_fd() {
        let mut m = tiny_cnn(3);
        let mut rng = Rng::seed(4);
        let x = Tensor::randn(&[2, 1, 8, 8], 1.0, &mut rng);
        // loss = Σ y² / 2 → dy = y
        m.zero_grad();
        let y = m.forward_train(&x);
        let _ = m.backward(&y);
        // collect analytic grads
        let mut grads = Vec::new();
        m.visit_params(&mut |_, g| grads.push(g.clone()));
        // probe a few params in the first conv (index 0 of visit order)
        let eps = 1e-2f32;
        let loss = |m: &mut Model, x: &Tensor| {
            let y = m.forward_train(x);
            y.data().iter().map(|&v| 0.5 * v * v).sum::<f32>()
        };
        for &pi in &[0usize, 3, 17] {
            let mut mp = m.clone();
            let mut count = 0;
            mp.visit_params(&mut |p, _| {
                if count == 0 {
                    p.data_mut()[pi] += eps;
                }
                count += 1;
            });
            let mut mm = m.clone();
            let mut count = 0;
            mm.visit_params(&mut |p, _| {
                if count == 0 {
                    p.data_mut()[pi] -= eps;
                }
                count += 1;
            });
            let fd = (loss(&mut mp, &x) - loss(&mut mm, &x)) / (2.0 * eps);
            let got = grads[0].data()[pi];
            assert!(
                (fd - got).abs() < 0.05 * (1.0 + fd.abs()),
                "param[{pi}]: fd {fd} vs analytic {got}"
            );
        }
    }

    #[test]
    fn branches_concat_and_backward() {
        let mut rng = Rng::seed(5);
        let mut m = Model::new(
            "branchy",
            vec![Layer::Branches(vec![
                vec![Layer::Conv(ConvLayer::new(Conv2dSpec::new(2, 3, 1, 1, 0), false, &mut rng))],
                vec![Layer::Conv(ConvLayer::new(Conv2dSpec::new(2, 5, 3, 1, 1), false, &mut rng))],
            ])],
        );
        let x = Tensor::randn(&[1, 2, 4, 4], 1.0, &mut rng);
        let y = m.forward_train(&x);
        assert_eq!(y.dims(), &[1, 8, 4, 4]); // 3 + 5 channels
        let dx = m.backward(&Tensor::full(y.dims(), 1.0));
        assert_eq!(dx.dims(), x.dims());
        assert!(dx.max_abs() > 0.0);
    }

    #[test]
    fn fold_bn_removes_bns_and_preserves_forward() {
        let mut m = tiny_cnn(7);
        let mut rng = Rng::seed(8);
        // give BNs non-trivial stats by doing a training pass
        let x = Tensor::randn(&[4, 1, 8, 8], 1.0, &mut rng);
        let _ = m.forward_train(&x);
        let want = m.forward(&x);
        let mut folded = m.clone();
        folded.fold_bn();
        fn count_bn(layers: &[Layer]) -> usize {
            layers
                .iter()
                .map(|l| match l {
                    Layer::Bn(_) => 1,
                    Layer::Residual(m, s) => count_bn(m) + count_bn(s),
                    Layer::Branches(bs) => bs.iter().map(|b| count_bn(b)).sum(),
                    _ => 0,
                })
                .sum()
        }
        assert_eq!(count_bn(&folded.layers), 0);
        let got = folded.forward(&x);
        for (a, b) in want.data().iter().zip(got.data()) {
            assert!((a - b).abs() < 1e-3, "{a} vs {b}");
        }
    }

    #[test]
    fn act_quant_layer_quantizes_forward_passes_grad() {
        let r = Range { bias: 0.0, half_width: 1.0 };
        let mut l = Layer::ActQuant(r, BitSpec::int(2));
        let x = Tensor::vec1(&[0.3, -0.9, 0.77]);
        let y = l.forward(&x);
        // INT2 step = 0.5: values snap to the grid
        for v in y.data() {
            assert!((v / 0.5 - (v / 0.5).round()).abs() < 1e-6, "{v} not on grid");
        }
        let (_, cache) = l.forward_train(&x);
        let dy = Tensor::vec1(&[1.0, 2.0, 3.0]);
        let dx = l.backward(&dy, &cache);
        assert_eq!(dx.data(), dy.data()); // straight-through
    }
}
