//! Theorem 2 for arbitrary graphs — model-level low-bit expansion.
//!
//! `basis_slices(model, bits, terms)` builds `terms` isomorphic basis
//! models: slice `i` carries term `i` of every conv/linear weight's
//! series expansion (a scaled INT plane, §3.3's `model_i`), biases are
//! split `1/t` across slices (the paper's "copy other layers and divide"
//! rule), and a one-step activation quantizer is inserted after every
//! matmul layer (each basis model is a genuine low-bit model). The
//! coordinator evaluates the slices in parallel and AbelianAdd-reduces
//! their outputs.
//!
//! The reduction over slices equals the layer-sync quantized model only
//! up to the nonlinearity-interchange error (ReLU does not commute with
//! ⊎) — the gap Theorem 2's proof glosses over; `tests` and
//! EXPERIMENTS.md measure it instead of assuming it away.

use super::graph::{Layer, Model};
use crate::tensor::Tensor;
use crate::xint::expansion::{ExpandConfig, SeriesExpansion};
use crate::xint::quantizer::{channel_range, Clip, Symmetry};
use crate::xint::BitSpec;

/// Build the `terms` basis slices of a (BN-folded) model.
pub fn basis_slices(model: &Model, bits: u32, terms: usize) -> Vec<Model> {
    assert!(terms >= 1);
    let mut folded = model.clone();
    folded.fold_bn();
    let w_cfg = ExpandConfig {
        bits: BitSpec::int(bits),
        terms,
        symmetry: Symmetry::Symmetric,
        clip: Clip::None,
        channel_axis: Some(0),
    };
    (0..terms)
        .map(|slice| {
            let mut m = folded.clone();
            m.name = format!("{}-basis{}", model.name, slice);
            slice_layers(&mut m.layers, slice, terms, &w_cfg, bits);
            m
        })
        .collect()
}

fn slice_layers(layers: &mut Vec<Layer>, slice: usize, terms: usize, w_cfg: &ExpandConfig, bits: u32) {
    let mut i = 0;
    while i < layers.len() {
        let mut insert_quant = false;
        match &mut layers[i] {
            Layer::Conv(c) => {
                let flat_dims = [c.w.dims()[0], c.w.numel() / c.w.dims()[0]];
                let flat = c.w.reshape(&flat_dims);
                let e = SeriesExpansion::expand(&flat, w_cfg);
                c.w = e.term_tensor(slice).reshaped(c.w.dims());
                if let Some(b) = &mut c.b {
                    *b = b.scale(1.0 / terms as f32);
                }
                insert_quant = true;
            }
            Layer::Linear(l) => {
                let e = SeriesExpansion::expand(&l.w, w_cfg);
                l.w = e.term_tensor(slice);
                if let Some(b) = &mut l.b {
                    *b = b.scale(1.0 / terms as f32);
                }
                insert_quant = true;
            }
            Layer::Residual(m, s) => {
                slice_layers(m, slice, terms, w_cfg, bits);
                slice_layers(s, slice, terms, w_cfg, bits);
            }
            Layer::Branches(bs) => {
                for b in bs.iter_mut() {
                    slice_layers(b, slice, terms, w_cfg, bits);
                }
            }
            Layer::Bn(_) => panic!("fold_bn before slicing"),
            _ => {}
        }
        if insert_quant {
            // one-step activation quantizer; range resolved lazily at the
            // first forward would need state — use a generous static range
            // refreshed by calibrate_slices()
            layers.insert(
                i + 1,
                Layer::ActQuant(
                    crate::xint::quantizer::Range { bias: 0.0, half_width: 0.0 },
                    BitSpec::int(bits),
                ),
            );
            i += 1;
        }
        i += 1;
    }
}

/// Calibrate every ActQuant range in each slice on a probe batch (ranges
/// observed on the *slice's own* activations — each basis model sees its
/// own scale `s_i` worth of signal).
pub fn calibrate_slices(slices: &mut [Model], probe: &Tensor, bits: u32) {
    for m in slices {
        calibrate_walk(&mut m.layers, probe, bits);
    }
}

fn calibrate_walk(layers: &mut [Layer], x: &Tensor, bits: u32) -> Tensor {
    let mut h = x.clone();
    let mut i = 0;
    while i < layers.len() {
        match &mut layers[i] {
            Layer::Residual(m, s) => {
                let hm = calibrate_walk(m, &h, bits);
                let hs = calibrate_walk(s, &h, bits);
                h = hm.add(&hs);
            }
            Layer::Branches(bs) => {
                let outs: Vec<Tensor> =
                    bs.iter_mut().map(|b| calibrate_walk(b, &h, bits)).collect();
                h = super::graph::concat_channels_pub(&outs);
            }
            Layer::ActQuant(r, _) => {
                *r = channel_range(h.data(), Symmetry::Symmetric, Clip::None, bits);
                h = layers[i].forward(&h);
            }
            other => {
                h = other.forward(&h);
            }
        }
        i += 1;
    }
    h
}

/// Evaluate the AllReduce of the slices on a batch.
pub fn forward_reduced(slices: &[Model], x: &Tensor) -> Tensor {
    crate::xint::abelian::abelian_reduce(slices.iter().map(|m| m.forward(x)).collect())
        .expect("at least one slice")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets::SynthImg;
    use crate::models::zoo;
    use crate::train::TrainConfig;
    use std::sync::OnceLock;

    static FIX: OnceLock<(Model, SynthImg)> = OnceLock::new();

    fn fixture() -> &'static (Model, SynthImg) {
        FIX.get_or_init(|| {
            let data = SynthImg::new(4, 1, 12, 0.2, 301);
            let mut m = zoo::mini_resnet_a(4, 302);
            let cfg = TrainConfig { steps: 100, batch: 24, lr: 0.05, log_every: 1000 };
            crate::train::train_classifier(&mut m, &data, &cfg);
            (m, data)
        })
    }

    #[test]
    fn slices_are_isomorphic_and_low_bit() {
        let (m, data) = fixture();
        let mut slices = basis_slices(m, 8, 3);
        assert_eq!(slices.len(), 3);
        let probe = data.batch(8, 3).x;
        calibrate_slices(&mut slices, &probe, 8);
        // every slice runs and produces the same output shape
        for s in &slices {
            let y = s.forward(&probe);
            assert_eq!(y.dims(), &[8, 4], "{}", s.name);
            assert!(y.data().iter().all(|v| v.is_finite()), "{}", s.name);
        }
    }

    #[test]
    fn weight_sum_over_slices_reconstructs_folded_weights() {
        // Σᵢ W_i == folded FP weights within the Theorem-1 bound —
        // the weight-side half of Theorem 2 is exact
        let (m, _) = fixture();
        let terms = 3;
        let slices = basis_slices(m, 8, terms);
        let mut folded = m.clone();
        folded.fold_bn();
        // compare the first conv weight
        let first_conv = |mm: &Model| -> Tensor {
            for l in &mm.layers {
                if let Layer::Conv(c) = l {
                    return c.w.clone();
                }
            }
            panic!("no conv")
        };
        let want = first_conv(&folded);
        let mut sum = Tensor::zeros(want.dims());
        for s in &slices {
            sum.axpy(1.0, &first_conv(s));
        }
        let err = want.sub(&sum).max_abs();
        // 8-bit × 3 terms → residual ≤ max|w| / 2^{8·3+1}, ~float noise
        assert!(err < 1e-4 * (1.0 + want.max_abs()), "weight sum err {err}");
    }

    #[test]
    fn reduced_slices_track_fp_and_improve_with_terms() {
        let (m, data) = fixture();
        let probe = data.batch(32, 3).x;
        let val = data.batch(128, 2);
        let mut folded = m.clone();
        folded.fold_bn();
        let fp_acc = crate::datasets::accuracy(&folded.forward(&val.x), &val.y);
        let acc_of = |terms: usize| {
            let mut slices = basis_slices(m, 8, terms);
            calibrate_slices(&mut slices, &probe, 8);
            let y = forward_reduced(&slices, &val.x);
            crate::datasets::accuracy(&y, &val.y)
        };
        let a2 = acc_of(2);
        let a4 = acc_of(4);
        // Honest Theorem-2 finding (soundness 0/5 in the calibration
        // bands): the t diagonal slices drop all (i≠j) cross terms AND
        // split biases 1/t, so ReLU(Wᵢx + b/t) errors COMPOUND with both
        // depth and t — measured here: t=2 is near-FP (term 0 dominates
        // at 8 bits) while t=4 drops tens of points. Model-parallel mode
        // is therefore only exact for shallow/linear nets; deep nets need
        // the layer-sync mode (which all accuracy tables use). Quantified
        // in EXPERIMENTS.md as a paper-claim deviation.
        assert!(a2 >= fp_acc - 0.05, "t=2 should be near FP: {a2:.3} vs {fp_acc:.3}");
        assert!(a4 > 0.40, "t=4 slices acc {a4:.3} (chance 0.25)");
        assert!(
            a4 <= a2 + 0.02,
            "expected the interchange error to grow with t: {a2:.3} -> {a4:.3}"
        );
    }

    #[test]
    fn interchange_gap_is_measurable_and_bounded() {
        // quantify the Theorem-2 gap: reduced-slices output vs the
        // layer-sync quantized model output
        let (m, data) = fixture();
        let probe = data.batch(16, 3).x;
        let mut slices = basis_slices(m, 8, 3);
        calibrate_slices(&mut slices, &probe, 8);
        let y_par = forward_reduced(&slices, &probe);
        let q = crate::models::quantized::quantize_model(
            m,
            crate::xint::layer::LayerPolicy::new(8, 8).with_terms(3, 2),
        );
        let y_sync = q.forward(&probe);
        let gap = y_sync.sub(&y_par).norm() / y_sync.norm();
        // nonzero (ReLU doesn't commute with ⊎) but bounded
        assert!(gap > 1e-6, "gap suspiciously zero");
        assert!(gap < 1.0, "interchange gap blew up: {gap}");
    }
}
