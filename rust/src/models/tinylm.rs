//! Tiny causal char-level LM — the LLM stand-in for Table 6 (MMLU under
//! W4A16 weight-only expansion). Reuses [`super::tinybert::EncoderBlock`]
//! with the causal mask; scoring follows the MMLU base-model protocol:
//! pick the answer choice with the highest sequence log-likelihood.

use super::tinybert::EncoderBlock;
use crate::datasets::charlm::{encode_char, McQuestion, CHAR_VOCAB};
use crate::tensor::{matmul, matmul_a_bt, matmul_at_b, Rng, Tensor};
use crate::xint::layer::LayerPolicy;
use crate::xint::SeriesExpansion;

/// Causal transformer LM over the 28-char vocabulary.
#[derive(Clone, Debug)]
pub struct TinyLm {
    pub d: usize,
    pub seq: usize,
    pub embed: Tensor, // (vocab, d)
    pub pos: Tensor,   // (seq, d)
    pub blocks: Vec<EncoderBlock>,
    pub w_out: Tensor, // (vocab, d)
    pub gembed: Tensor,
    pub gpos: Tensor,
    pub gout: Tensor,
    cache: Option<(Vec<Vec<usize>>, Tensor)>,
}

impl TinyLm {
    pub fn new(d: usize, ff: usize, layers: usize, seq: usize, seed: u64) -> Self {
        let mut rng = Rng::seed(seed);
        TinyLm {
            d,
            seq,
            embed: Tensor::randn(&[CHAR_VOCAB, d], 0.5, &mut rng),
            pos: Tensor::randn(&[seq, d], 0.1, &mut rng),
            blocks: (0..layers).map(|_| EncoderBlock::new(d, ff, &mut rng)).collect(),
            w_out: Tensor::randn(&[CHAR_VOCAB, d], (1.0 / d as f32).sqrt(), &mut rng),
            gembed: Tensor::zeros(&[CHAR_VOCAB, d]),
            gpos: Tensor::zeros(&[seq, d]),
            gout: Tensor::zeros(&[CHAR_VOCAB, d]),
            cache: None,
        }
    }

    fn embed_batch(&self, tokens: &[Vec<usize>]) -> Tensor {
        let n = tokens.len();
        let mut x = Tensor::zeros(&[n * self.seq, self.d]);
        for (s, seq) in tokens.iter().enumerate() {
            for (p, &tok) in seq.iter().enumerate() {
                let dst = (s * self.seq + p) * self.d;
                for j in 0..self.d {
                    x.data_mut()[dst + j] =
                        self.embed.data()[tok * self.d + j] + self.pos.data()[p * self.d + j];
                }
            }
        }
        x
    }

    /// Next-token logits at every position: (N·T, vocab).
    pub fn forward(&self, tokens: &[Vec<usize>]) -> Tensor {
        let n = tokens.len();
        let mut h = self.embed_batch(tokens);
        for b in &self.blocks {
            h = b.forward(&h, n, self.seq, true);
        }
        matmul_a_bt(&h, &self.w_out)
    }

    pub fn forward_train(&mut self, tokens: &[Vec<usize>]) -> Tensor {
        let n = tokens.len();
        let mut h = self.embed_batch(tokens);
        for b in &mut self.blocks {
            h = b.forward_train(&h, n, self.seq, true);
        }
        self.cache = Some((tokens.to_vec(), h.clone()));
        matmul_a_bt(&h, &self.w_out)
    }

    pub fn backward(&mut self, dlogits: &Tensor) {
        let (tokens, feat) = self.cache.take().expect("forward_train first");
        let n = tokens.len();
        self.gout.axpy(1.0, &matmul_at_b(dlogits, &feat));
        let mut g = matmul(dlogits, &self.w_out);
        for b in self.blocks.iter_mut().rev() {
            g = b.backward(&g, n, self.seq, true);
        }
        for (s, seq) in tokens.iter().enumerate() {
            for (p, &tok) in seq.iter().enumerate() {
                let src = (s * self.seq + p) * self.d;
                for j in 0..self.d {
                    self.gembed.data_mut()[tok * self.d + j] += g.data()[src + j];
                    self.gpos.data_mut()[p * self.d + j] += g.data()[src + j];
                }
            }
        }
    }

    pub fn zero_grad(&mut self) {
        self.gembed.map_inplace(|_| 0.0);
        self.gpos.map_inplace(|_| 0.0);
        self.gout.map_inplace(|_| 0.0);
        for b in &mut self.blocks {
            b.zero_grad();
        }
    }

    pub fn visit_params(&mut self, f: &mut dyn FnMut(&mut Tensor, &Tensor)) {
        f(&mut self.embed, &self.gembed.clone());
        f(&mut self.pos, &self.gpos.clone());
        f(&mut self.w_out, &self.gout.clone());
        for b in &mut self.blocks {
            b.visit_params(f);
        }
    }

    pub fn params(&self) -> usize {
        self.embed.numel()
            + self.pos.numel()
            + self.w_out.numel()
            + self.blocks.iter().map(|b| b.params()).sum::<usize>()
    }

    /// Log-likelihood of `text` continuing after `stem` (sum of next-char
    /// log-probs over the continuation region).
    pub fn continuation_ll(&self, stem: &str, cont: &str) -> f64 {
        let mut toks: Vec<usize> = format!("{stem}{cont}").bytes().map(encode_char).collect();
        let stem_len = stem.len();
        toks.truncate(self.seq);
        while toks.len() < self.seq {
            toks.push(encode_char(b' '));
        }
        let logits = self.forward(&[toks.clone()]);
        let ls = logits.log_softmax_rows();
        let end = (stem_len + cont.len()).min(self.seq);
        let mut ll = 0.0f64;
        for p in stem_len.saturating_sub(1)..end.saturating_sub(1) {
            let next = toks[p + 1];
            ll += ls.at(&[p, next]) as f64;
        }
        ll
    }

    /// MMLU protocol: answer = argmax choice log-likelihood.
    pub fn answer(&self, q: &McQuestion) -> usize {
        let mut best = (0usize, f64::NEG_INFINITY);
        for (i, c) in q.choices.iter().enumerate() {
            let ll = self.continuation_ll(&q.stem, c);
            if ll > best.1 {
                best = (i, ll);
            }
        }
        best.0
    }

    /// W4A16-style weight-only PTQ: expand block weights at `policy`,
    /// embeddings/head at 8-bit (the paper's first/last rule).
    pub fn quantize_weights(&mut self, policy: &LayerPolicy) {
        let e_cfg = LayerPolicy::eight_bit().weight_config();
        self.embed = SeriesExpansion::expand(&self.embed, &e_cfg).reconstruct();
        for b in &mut self.blocks {
            b.quantize_weights(policy);
        }
        self.w_out = SeriesExpansion::expand(&self.w_out, &e_cfg).reconstruct();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets::charlm::CharLmTask;

    #[test]
    fn forward_shape() {
        let lm = TinyLm::new(8, 16, 1, 12, 1);
        let toks = vec![vec![0usize; 12], vec![1usize; 12]];
        let y = lm.forward(&toks);
        assert_eq!(y.dims(), &[24, CHAR_VOCAB]);
    }

    #[test]
    fn lm_learns_repetition() {
        // train on a trivially predictable stream; loss must drop
        let mut lm = TinyLm::new(8, 16, 1, 8, 2);
        let stream: Vec<usize> = "abcabcabcabcabcabcabcabc".bytes().map(encode_char).collect();
        let mk_batch = |off: usize| -> Vec<Vec<usize>> {
            vec![stream[off..off + 8].to_vec(), stream[off + 3..off + 11].to_vec()]
        };
        let loss_of = |lm: &TinyLm, toks: &[Vec<usize>]| {
            let logits = lm.forward(toks);
            let ls = logits.log_softmax_rows();
            let mut l = 0.0f32;
            let mut count = 0;
            for (s, seq) in toks.iter().enumerate() {
                for p in 0..7 {
                    l -= ls.at(&[s * 8 + p, seq[p + 1]]);
                    count += 1;
                }
            }
            l / count as f32
        };
        let toks = mk_batch(0);
        let l0 = loss_of(&lm, &toks);
        for step in 0..60 {
            let batch = mk_batch(step % 4);
            lm.zero_grad();
            let logits = lm.forward_train(&batch);
            let sm = logits.softmax_rows();
            let mut dl = sm.clone();
            let mut count = 0.0f32;
            for (s, seq) in batch.iter().enumerate() {
                for p in 0..7 {
                    dl.data_mut()[(s * 8 + p) * CHAR_VOCAB + seq[p + 1]] -= 1.0;
                    count += 1.0;
                }
                // zero grads at the last position (no target)
                for j in 0..CHAR_VOCAB {
                    dl.data_mut()[(s * 8 + 7) * CHAR_VOCAB + j] = 0.0;
                }
            }
            let dl = dl.scale(1.0 / count);
            lm.backward(&dl);
            lm.visit_params(&mut |p, g| p.axpy(-1.0, g));
        }
        let l1 = loss_of(&lm, &toks);
        assert!(l1 < l0 * 0.6, "LM loss {l0} -> {l1}");
    }

    #[test]
    fn answer_returns_valid_choice() {
        let lm = TinyLm::new(8, 16, 1, 32, 3);
        let task = CharLmTask::new(4);
        for q in task.questions().iter().take(4) {
            assert!(lm.answer(q) < 4);
        }
    }

    #[test]
    fn w8_weight_quant_preserves_ll_ordering_better_than_w2() {
        let lm = TinyLm::new(8, 16, 1, 16, 5);
        let stem = "the plato ";
        let conts = ["wrote epics.", "sang odes."];
        let fp: Vec<f64> = conts.iter().map(|c| lm.continuation_ll(stem, c)).collect();
        let mut q8 = lm.clone();
        q8.quantize_weights(&LayerPolicy::new(8, 16).with_terms(2, 1));
        let l8: Vec<f64> = conts.iter().map(|c| q8.continuation_ll(stem, c)).collect();
        // 8-bit 2-term weight expansion keeps log-likelihoods close
        for (a, b) in fp.iter().zip(&l8) {
            assert!((a - b).abs() < 0.1 * (1.0 + a.abs()), "{a} vs {b}");
        }
    }
}
