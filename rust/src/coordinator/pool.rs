//! Basis-model worker pool.
//!
//! Each worker thread owns one basis model `model_i` (Theorem 2). The
//! factory runs *inside* the thread, so non-`Send` state (a PJRT client)
//! is constructed where it lives. Broadcast jobs fan the same activation
//! out to every worker — the paper's "broadcast and quantize" step
//! (§5.1: "the activations of all base models are broadcast") — along
//! with the batch's [`BudgetPlan`] (shared by `Arc`: one plan per batch,
//! not one clone per worker).

use crate::obs::{SpanKind, TraceRecorder};
use crate::qos::Tier;
use crate::tensor::Tensor;
use crate::util::sync::thread::JoinHandle;
use crate::util::sync::{mpsc, thread, Arc};
use crate::xint::budget::{BudgetPlan, LayerTrace};

/// One worker invocation's result: the partial output plus what the
/// worker actually spent on it (0 when the backend has no Eq. 3 grid
/// to meter — e.g. the Theorem-2 basis slices, which are themselves
/// single terms).
pub struct BudgetedRun {
    pub y: Tensor,
    /// INT GEMM `(i, j)` terms executed inside the worker
    pub grid_terms: usize,
    /// per-layer execution record (empty when the backend doesn't
    /// meter its grid) — the trace plane turns these into `layer_grid`
    /// spans nested inside the worker's span
    pub layer_traces: Vec<LayerTrace>,
}

/// Trace context attached to a dispatched job. Worker spans are
/// recorded once per request trace id, so every request in the batch
/// gets a complete span chain even though the execution is shared.
#[derive(Clone)]
pub struct SpanCtx {
    pub recorder: Arc<TraceRecorder>,
    /// trace ids of every request in the batch being executed
    pub trace_ids: Arc<Vec<u64>>,
    pub tier: Tier,
}

/// Reply channel of one dispatched job (worker index + its result).
pub type RunReceiver = mpsc::Receiver<(usize, anyhow::Result<BudgetedRun>)>;

/// One basis model's compute: activation batch in, partial output out.
///
/// Deliberately NOT `Send`: workers are constructed *inside* their thread
/// by the factory and never move, which lets a worker own a PJRT client
/// (`Rc`-based in the `xla` crate).
pub trait BasisWorker {
    fn run(&mut self, x: &Tensor) -> anyhow::Result<Tensor>;

    /// Plan-aware entry point. The default ignores the plan and
    /// reports no grid spend, so existing workers keep their exact
    /// behavior; backends with a runtime-truncatable term grid
    /// (`QuantModelWorker`) override it and index the plan per layer.
    fn run_budgeted(&mut self, x: &Tensor, plan: &BudgetPlan) -> anyhow::Result<BudgetedRun> {
        let _ = plan;
        Ok(BudgetedRun { y: self.run(x)?, grid_terms: 0, layer_traces: Vec::new() })
    }
}

/// Factory constructing worker `i` inside its thread. The factory itself
/// must be Send+Sync (shared across spawns); the worker it builds only
/// needs to live on its own thread.
pub type WorkerFactory = Arc<dyn Fn(usize) -> Box<dyn BasisWorker> + Send + Sync>;

enum Job {
    Broadcast {
        x: Arc<Tensor>,
        plan: Arc<BudgetPlan>,
        out: mpsc::Sender<(usize, anyhow::Result<BudgetedRun>)>,
        ctx: Option<SpanCtx>,
    },
    Stop,
}

/// Record the worker-side spans for one finished job: a `worker_term`
/// span per request trace id (error-flagged when the run failed), with
/// the worker's per-layer grid records nested inside it as `layer_grid`
/// spans (offsets re-anchored to the worker span's start, clamped so
/// children never outlive the parent).
fn record_worker_spans(ctx: &SpanCtx, i: usize, t0: u64, res: &anyhow::Result<BudgetedRun>) {
    let t1 = ctx.recorder.now_ns();
    let (err, grid, traces): (bool, u64, &[LayerTrace]) = match res {
        Ok(run) => (false, run.grid_terms as u64, &run.layer_traces),
        Err(_) => (true, 0, &[]),
    };
    for &id in ctx.trace_ids.iter() {
        ctx.recorder
            .record_span(id, SpanKind::WorkerTerm, ctx.tier, err, t0, t1, [i as u64, grid, 0]);
        for lt in traces {
            let s = (t0 + lt.t_start_ns).min(t1);
            let e = (t0 + lt.t_end_ns).min(t1);
            ctx.recorder.record_span(
                id,
                SpanKind::LayerGrid,
                ctx.tier,
                false,
                s,
                e,
                [lt.index as u64, lt.grid_terms as u64, lt.planned_grid as u64],
            );
        }
    }
}

/// Fixed pool of basis workers.
pub struct WorkerPool {
    senders: Vec<mpsc::Sender<Job>>,
    handles: Vec<JoinHandle<()>>,
}

impl WorkerPool {
    pub fn new(n: usize, factory: WorkerFactory) -> WorkerPool {
        assert!(n > 0, "pool needs at least one worker");
        // compose the two parallelism levels: with n basis workers each
        // running layer grids concurrently, cap the intra-op kernel
        // lanes at available_parallelism / n so kernel row-blocking
        // doesn't oversubscribe the cores the pool already claimed
        crate::xint::kernel::set_interop_workers(n);
        let mut senders = Vec::with_capacity(n);
        let mut handles = Vec::with_capacity(n);
        for i in 0..n {
            let (tx, rx) = mpsc::channel::<Job>();
            let factory = factory.clone();
            handles.push(
                thread::Builder::new()
                    .name(format!("basis-worker-{i}"))
                    .spawn(move || {
                        let mut worker = factory(i);
                        while let Ok(job) = rx.recv() {
                            match job {
                                Job::Broadcast { x, plan, out, ctx } => {
                                    let t0 = ctx.as_ref().map(|c| c.recorder.now_ns());
                                    let res = worker.run_budgeted(&x, &plan);
                                    if let (Some(c), Some(t0)) = (&ctx, t0) {
                                        record_worker_spans(c, i, t0, &res);
                                    }
                                    // receiver may be gone on shutdown
                                    let _ = out.send((i, res));
                                }
                                Job::Stop => break,
                            }
                        }
                    })
                    .expect("spawn worker"),
            );
            senders.push(tx);
        }
        WorkerPool { senders, handles }
    }

    pub fn len(&self) -> usize {
        self.senders.len()
    }

    pub fn is_empty(&self) -> bool {
        self.senders.is_empty()
    }

    /// Broadcast `x` to all workers, collect all outputs in worker order.
    pub fn broadcast(&self, x: Tensor) -> anyhow::Result<Vec<Tensor>> {
        self.broadcast_to(x, self.senders.len())
    }

    /// Broadcast `x` to the first `n` workers only — the truncated-series
    /// path: because ⊎ prefix sums are themselves group elements, the
    /// first `n` basis outputs reduce to a valid lower-precision model
    /// (the QoS tiers ride this). Outputs return in worker order 0..n.
    pub fn broadcast_to(&self, x: Tensor, n: usize) -> anyhow::Result<Vec<Tensor>> {
        Ok(self
            .broadcast_runs(x, n, Arc::new(BudgetPlan::full()))?
            .into_iter()
            .map(|r| r.y)
            .collect())
    }

    /// [`WorkerPool::broadcast_to`] with an explicit per-batch
    /// [`BudgetPlan`] — plan-aware workers truncate their own Eq. 3
    /// grids per layer and report the GEMM terms spent.
    pub fn broadcast_runs(
        &self,
        x: Tensor,
        n: usize,
        plan: Arc<BudgetPlan>,
    ) -> anyhow::Result<Vec<BudgetedRun>> {
        self.broadcast_runs_traced(x, n, plan, None)
    }

    /// [`WorkerPool::broadcast_runs`] with an optional [`SpanCtx`]: each
    /// worker records a `worker_term` span (plus nested `layer_grid`
    /// spans) for every trace id in the batch.
    pub fn broadcast_runs_traced(
        &self,
        x: Tensor,
        n: usize,
        plan: Arc<BudgetPlan>,
        ctx: Option<SpanCtx>,
    ) -> anyhow::Result<Vec<BudgetedRun>> {
        anyhow::ensure!(n >= 1, "broadcast needs at least one worker");
        anyhow::ensure!(n <= self.senders.len(), "prefix {n} exceeds pool {}", self.senders.len());
        let x = Arc::new(x);
        let (tx, rx) = mpsc::channel();
        for s in &self.senders[..n] {
            s.send(Job::Broadcast {
                x: x.clone(),
                plan: plan.clone(),
                out: tx.clone(),
                ctx: ctx.clone(),
            })
            .map_err(|_| anyhow::anyhow!("worker thread died"))?;
        }
        drop(tx);
        let mut outs: Vec<Option<BudgetedRun>> = Vec::new();
        outs.resize_with(n, || None);
        for _ in 0..n {
            let (i, res) = rx.recv().map_err(|_| anyhow::anyhow!("worker output lost"))?;
            outs[i] = Some(res?);
        }
        Ok(outs.into_iter().map(|o| o.expect("all workers reported")).collect())
    }

    /// Dispatch `x` to worker `i` WITHOUT waiting: returns the reply
    /// channel. This is the primitive under the streamed anytime path's
    /// one-term-lookahead pipeline — the scheduler keeps exactly one
    /// speculative dispatch in flight while it inspects the previous
    /// term, so an early stop wastes at most one worker run.
    pub fn dispatch_one(
        &self,
        i: usize,
        x: Arc<Tensor>,
        plan: Arc<BudgetPlan>,
    ) -> anyhow::Result<RunReceiver> {
        self.dispatch_one_traced(i, x, plan, None)
    }

    /// [`WorkerPool::dispatch_one`] with an optional [`SpanCtx`].
    pub fn dispatch_one_traced(
        &self,
        i: usize,
        x: Arc<Tensor>,
        plan: Arc<BudgetPlan>,
        ctx: Option<SpanCtx>,
    ) -> anyhow::Result<RunReceiver> {
        anyhow::ensure!(
            i < self.senders.len(),
            "worker {i} out of range (pool of {})",
            self.senders.len()
        );
        let (tx, rx) = mpsc::channel();
        self.senders[i]
            .send(Job::Broadcast { x, plan, out: tx, ctx })
            .map_err(|_| anyhow::anyhow!("worker thread died"))?;
        Ok(rx)
    }

    /// Run `x` on worker `i` alone and wait for its output.
    pub fn run_one(&self, i: usize, x: Arc<Tensor>) -> anyhow::Result<Tensor> {
        let rx = self.dispatch_one(i, x, Arc::new(BudgetPlan::full()))?;
        let (_, res) = rx.recv().map_err(|_| anyhow::anyhow!("worker output lost"))?;
        Ok(res?.y)
    }

    /// Stop all workers and join.
    pub fn shutdown(self) {
        for s in &self.senders {
            let _ = s.send(Job::Stop);
        }
        for h in self.handles {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Rng;
    use crate::xint::budget::TermBudget;

    struct AddConst(f32);
    impl BasisWorker for AddConst {
        fn run(&mut self, x: &Tensor) -> anyhow::Result<Tensor> {
            Ok(x.map(|v| v + self.0))
        }
    }

    #[test]
    fn broadcast_collects_in_worker_order() {
        let pool = WorkerPool::new(
            3,
            Arc::new(|i| Box::new(AddConst(i as f32)) as Box<dyn BasisWorker>),
        );
        let x = Tensor::vec1(&[10.0]);
        let outs = pool.broadcast(x).unwrap();
        assert_eq!(outs.len(), 3);
        for (i, o) in outs.iter().enumerate() {
            assert_eq!(o.data(), &[10.0 + i as f32], "worker {i}");
        }
        pool.shutdown();
    }

    #[test]
    fn broadcast_to_prefix_only_runs_first_workers() {
        let pool = WorkerPool::new(
            4,
            Arc::new(|i| Box::new(AddConst(i as f32)) as Box<dyn BasisWorker>),
        );
        let outs = pool.broadcast_to(Tensor::vec1(&[1.0]), 2).unwrap();
        assert_eq!(outs.len(), 2);
        assert_eq!(outs[0].data(), &[1.0]);
        assert_eq!(outs[1].data(), &[2.0]);
        assert!(pool.broadcast_to(Tensor::vec1(&[1.0]), 0).is_err());
        assert!(pool.broadcast_to(Tensor::vec1(&[1.0]), 5).is_err());
        pool.shutdown();
    }

    #[test]
    fn run_one_targets_a_single_worker() {
        let pool = WorkerPool::new(
            3,
            Arc::new(|i| Box::new(AddConst(i as f32)) as Box<dyn BasisWorker>),
        );
        let x = Arc::new(Tensor::vec1(&[5.0]));
        assert_eq!(pool.run_one(2, x.clone()).unwrap().data(), &[7.0]);
        assert!(pool.run_one(3, x).is_err(), "out-of-range worker index");
        pool.shutdown();
    }

    #[test]
    fn plan_reaches_workers_and_spend_reports_back() {
        struct PlanEcho;
        impl BasisWorker for PlanEcho {
            fn run(&mut self, x: &Tensor) -> anyhow::Result<Tensor> {
                Ok(x.clone())
            }
            fn run_budgeted(
                &mut self,
                x: &Tensor,
                plan: &BudgetPlan,
            ) -> anyhow::Result<BudgetedRun> {
                // report layer 0's (clamped) activation cap as "spend"
                Ok(BudgetedRun {
                    y: x.clone(),
                    grid_terms: plan.budget_for(0).a_terms.min(100),
                    layer_traces: Vec::new(),
                })
            }
        }
        let pool =
            WorkerPool::new(2, Arc::new(|_| Box::new(PlanEcho) as Box<dyn BasisWorker>));
        let plan = Arc::new(BudgetPlan::uniform(TermBudget::new(2, 3)));
        let runs = pool.broadcast_runs(Tensor::vec1(&[1.0]), 2, plan).unwrap();
        assert!(runs.iter().all(|r| r.grid_terms == 3));
        // a per-layer plan is indexed by position inside the worker
        let plan = Arc::new(BudgetPlan::per_layer(
            vec![TermBudget::new(2, 7)],
            TermBudget::full(),
        ));
        let runs = pool.broadcast_runs(Tensor::vec1(&[1.0]), 2, plan).unwrap();
        assert!(runs.iter().all(|r| r.grid_terms == 7));
        // the plan-free API defaults to a full plan
        let runs = pool
            .broadcast_runs(Tensor::vec1(&[1.0]), 2, Arc::new(BudgetPlan::full()))
            .unwrap();
        assert!(runs.iter().all(|r| r.grid_terms == 100));
        // workers without an override report zero spend
        let plain =
            WorkerPool::new(1, Arc::new(|i| Box::new(AddConst(i as f32)) as Box<dyn BasisWorker>));
        let cheap = Arc::new(BudgetPlan::uniform(TermBudget::new(1, 1)));
        let runs = plain.broadcast_runs(Tensor::vec1(&[1.0]), 1, cheap).unwrap();
        assert_eq!(runs[0].grid_terms, 0);
        assert_eq!(runs[0].y.data(), &[1.0]);
        pool.shutdown();
        plain.shutdown();
    }

    #[test]
    fn traced_broadcast_records_worker_spans_per_trace_id() {
        let pool = WorkerPool::new(
            2,
            Arc::new(|i| Box::new(AddConst(i as f32)) as Box<dyn BasisWorker>),
        );
        let recorder = Arc::new(TraceRecorder::new(64));
        let ctx = SpanCtx {
            recorder: recorder.clone(),
            trace_ids: Arc::new(vec![7, 8]),
            tier: Tier::Balanced,
        };
        let runs = pool
            .broadcast_runs_traced(Tensor::vec1(&[1.0]), 2, Arc::new(BudgetPlan::full()), Some(ctx))
            .unwrap();
        assert_eq!(runs.len(), 2);
        let events = recorder.events();
        // 2 workers × 2 trace ids; AddConst has no layer grid to meter
        assert_eq!(events.len(), 4);
        for id in [7u64, 8] {
            let spans: Vec<_> = events.iter().filter(|e| e.trace_id == id).collect();
            assert_eq!(spans.len(), 2, "trace {id}");
            assert!(spans.iter().all(|e| e.span == SpanKind::WorkerTerm && !e.error));
            assert!(spans.iter().all(|e| e.tier == Tier::Balanced));
            assert!(spans.iter().all(|e| e.t_end_ns >= e.t_start_ns));
        }
        pool.shutdown();
    }

    #[test]
    fn worker_error_propagates() {
        struct Failing;
        impl BasisWorker for Failing {
            fn run(&mut self, _x: &Tensor) -> anyhow::Result<Tensor> {
                anyhow::bail!("boom")
            }
        }
        let pool =
            WorkerPool::new(2, Arc::new(|_| Box::new(Failing) as Box<dyn BasisWorker>));
        assert!(pool.broadcast(Tensor::vec1(&[1.0])).is_err());
        pool.shutdown();
    }

    #[test]
    fn parallel_speedup_on_sleepy_workers() {
        struct Sleepy;
        impl BasisWorker for Sleepy {
            fn run(&mut self, x: &Tensor) -> anyhow::Result<Tensor> {
                std::thread::sleep(std::time::Duration::from_millis(30));
                Ok(x.clone())
            }
        }
        let pool = WorkerPool::new(4, Arc::new(|_| Box::new(Sleepy) as Box<dyn BasisWorker>));
        let mut rng = Rng::seed(3);
        let x = Tensor::randn(&[2, 2], 1.0, &mut rng);
        let t0 = std::time::Instant::now();
        let outs = pool.broadcast(x).unwrap();
        let dt = t0.elapsed();
        assert_eq!(outs.len(), 4);
        // 4 workers × 30 ms run in parallel, not 120 ms serially —
        // the paper's "expansion cost hidden by parallelism" claim in
        // miniature (generous bound for CI noise)
        assert!(dt.as_millis() < 100, "broadcast took {dt:?}");
        pool.shutdown();
    }
}
