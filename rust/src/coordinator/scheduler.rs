//! Expansion scheduler: broadcast a formed batch to every basis worker,
//! AbelianAdd-reduce the partial outputs (tree order — valid because ⊎
//! is an Abelian group op), and scatter replies.

use super::batcher::FormedBatch;
use super::metrics::Metrics;
use super::pool::WorkerPool;
use super::Response;
use crate::tensor::Tensor;
use crate::xint::abelian::abelian_reduce;

pub struct ExpansionScheduler {
    pool: WorkerPool,
    /// optional per-worker output gains (AbelianMul scale application);
    /// length must equal the pool size when set
    gains: Option<Vec<f32>>,
}

impl ExpansionScheduler {
    pub fn new(pool: WorkerPool) -> ExpansionScheduler {
        ExpansionScheduler { pool, gains: None }
    }

    /// Apply per-basis output gains before reduction (the AbelianMul
    /// step: scale vectors distribute over ⊎).
    pub fn with_gains(mut self, gains: Vec<f32>) -> ExpansionScheduler {
        assert_eq!(gains.len(), self.pool.len());
        self.gains = Some(gains);
        self
    }

    /// Process one formed batch end to end.
    pub fn process(&self, batch: FormedBatch, metrics: &Metrics) {
        let t0 = std::time::Instant::now();
        let result = self.forward(batch.x.clone());
        match result {
            Ok(logits) => {
                let mut row = 0usize;
                let classes = logits.dims()[1];
                for (id, rows, reply, at) in batch.parts {
                    let data = logits.data()[row * classes..(row + rows) * classes].to_vec();
                    row += rows;
                    // record BEFORE sending: the caller may assert on the
                    // metrics immediately after receiving the reply
                    metrics.record_completed(at.elapsed().as_secs_f64());
                    let _ = reply.send(Response {
                        id,
                        logits: Tensor::from_vec(&[rows, classes], data),
                        latency_s: at.elapsed().as_secs_f64(),
                    });
                }
                metrics.record_batch(batch.x.dims()[0], t0.elapsed().as_secs_f64());
            }
            Err(e) => {
                log::error!("batch failed: {e:#}");
                metrics.record_failed(batch.parts.len());
                // drop replies: receivers observe RecvError
            }
        }
    }

    /// The core forward: broadcast → (gain ∘ output) → AbelianAdd tree.
    pub fn forward(&self, x: Tensor) -> anyhow::Result<Tensor> {
        let outs = self.pool.broadcast(x)?;
        let outs = match &self.gains {
            Some(g) => outs
                .into_iter()
                .zip(g)
                .map(|(o, &gain)| o.scale(gain))
                .collect(),
            None => outs,
        };
        abelian_reduce(outs).ok_or_else(|| anyhow::anyhow!("empty worker pool"))
    }

    pub fn shutdown(self) {
        self.pool.shutdown();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::pool::BasisWorker;
    use std::sync::Arc;

    struct Id;
    impl BasisWorker for Id {
        fn run(&mut self, x: &Tensor) -> anyhow::Result<Tensor> {
            Ok(x.clone())
        }
    }

    #[test]
    fn gains_apply_abelian_mul() {
        let pool = WorkerPool::new(3, Arc::new(|_| Box::new(Id) as Box<dyn BasisWorker>));
        let sched = ExpansionScheduler::new(pool).with_gains(vec![1.0, 0.5, 0.25]);
        let y = sched.forward(Tensor::vec1(&[8.0]).reshaped(&[1, 1])).unwrap();
        assert!((y.data()[0] - 14.0).abs() < 1e-5); // 8·(1+0.5+0.25)
        sched.shutdown();
    }
}
