//! Expansion scheduler: broadcast a formed batch to the basis workers,
//! AbelianAdd-reduce the partial outputs (tree order — valid because ⊎
//! is an Abelian group op), and scatter replies.
//!
//! With a [`TermController`] attached, the scheduler serves each batch
//! at its tier's term budget: it broadcasts only to the first `n`
//! workers of the pool (⊎ prefix sums are themselves group elements, so
//! the prefix is a valid lower-precision model) and feeds the
//! controller exactly ONE [`observe_batch`](TermController::observe_batch)
//! decision per formed batch — for the batch's OWN tier: its own queue
//! occupancy ([`FormedBatch::tier_occupancy`], not the cross-tier
//! hottest queue), its service time, and its tier's windowed
//! request-latency p99 (each reply's latency is pushed into the
//! controller's per-tier digest next to
//! [`Metrics::record_completed_tier`], then the window is consumed by
//! the decision). Failed batches feed occupancy relief only — their
//! service time and latencies never enter the EWMA or p99 digest, so
//! an erroring backend cannot masquerade as load. The scheduler runs
//! every worker under the tier's
//! [`BudgetPlan`] ([`TermController::plan_for`]) so plan-aware
//! replication workers truncate each layer's Eq. 3 grid to its
//! sensitivity-allocated entry. In *anytime* mode the prefix
//! is **streamed** with a one-term lookahead: terms dispatch in series
//! order with exactly one speculative dispatch in flight, and the
//! reduction stops once the marginal term's contribution falls below
//! the batch tolerance — at most one worker past the stop point ever
//! runs, so the early stop still saves basis compute while dispatch
//! overlaps the previous term's reduction. Failed batches send an
//! explicit error [`Response`] so protocol clients get an error frame
//! instead of a dropped channel.

use super::batcher::FormedBatch;
use super::metrics::Metrics;
use super::pool::{SpanCtx, WorkerPool};
use super::{RefineSink, Response, StreamFrame};
use crate::obs::{SpanKind, TraceRecorder};
use crate::qos::{TermController, NUM_TIERS};
use crate::tensor::Tensor;
use crate::util::sync::Arc;
use crate::xint::abelian::abelian_reduce;
use crate::xint::budget::BudgetPlan;

/// One reduced batch: the output, the basis terms reduced, and the INT
/// GEMM grid terms budget-aware workers reported executing.
struct Reduced {
    y: Tensor,
    terms: usize,
    grid_terms: usize,
}

/// Hooks threaded into the sequential anytime reduction by a batch
/// carrying progressive-refinement sinks.
struct StreamHooks<'a> {
    /// fired once per consumed term with the cumulative term count and
    /// the gained term tensor (the head term doubles as the prefix
    /// frame; every emitted term is already reduced into the answer)
    on_term: &'a dyn Fn(usize, &Tensor),
    /// polled at each loop head: true aborts further refinement
    cancelled: &'a dyn Fn() -> bool,
}

pub struct ExpansionScheduler {
    pool: WorkerPool,
    /// optional per-worker output gains (AbelianMul scale application);
    /// length must equal the pool size when set
    gains: Option<Vec<f32>>,
    /// optional per-tier output gains applied after the prefix reduction
    /// (e.g. bias-mass compensation for truncated split biases)
    tier_gains: Option<[f32; NUM_TIERS]>,
    /// QoS control plane; absent = every batch runs the full pool
    controller: Option<Arc<TermController>>,
    /// flight recorder; absent = tracing off, no span cost anywhere
    recorder: Option<Arc<TraceRecorder>>,
}

impl ExpansionScheduler {
    pub fn new(pool: WorkerPool) -> ExpansionScheduler {
        ExpansionScheduler {
            pool,
            gains: None,
            tier_gains: None,
            controller: None,
            recorder: None,
        }
    }

    /// Apply per-basis output gains before reduction (the AbelianMul
    /// step: scale vectors distribute over ⊎).
    pub fn with_gains(mut self, gains: Vec<f32>) -> ExpansionScheduler {
        assert_eq!(gains.len(), self.pool.len());
        self.gains = Some(gains);
        self
    }

    /// Apply a per-tier scalar to the reduced output (indexed by
    /// [`Tier::idx`](crate::qos::Tier::idx)); identity is `1.0`.
    pub fn with_tier_gains(mut self, tier_gains: [f32; NUM_TIERS]) -> ExpansionScheduler {
        self.tier_gains = Some(tier_gains);
        self
    }

    /// Attach the QoS control plane: per-tier truncation + pressure
    /// feedback + anytime early stopping. The controller must be sized
    /// for this pool — otherwise Exact-tier requests would be silently
    /// truncated to a budget smaller than the series.
    pub fn with_controller(mut self, controller: Arc<TermController>) -> ExpansionScheduler {
        assert_eq!(
            controller.config().total_terms,
            self.pool.len(),
            "controller total_terms must equal the worker-pool size"
        );
        self.controller = Some(controller);
        self
    }

    /// The attached QoS controller, if any — the serving layer keeps a
    /// handle so per-tier pressure is observable next to shed/queue
    /// stats ([`Coordinator::qos`](crate::coordinator::Coordinator)).
    pub fn controller(&self) -> Option<Arc<TermController>> {
        self.controller.clone()
    }

    /// Attach a flight recorder: every batch then records queue-wait,
    /// batch-formation, schedule, per-worker term, per-layer grid and
    /// reduce spans for each request it carries
    /// ([`Coordinator::new`](crate::coordinator::Coordinator) picks the
    /// handle up the same way it picks up the controller).
    pub fn with_recorder(mut self, recorder: Arc<TraceRecorder>) -> ExpansionScheduler {
        self.recorder = Some(recorder);
        self
    }

    /// The attached flight recorder, if any.
    pub fn recorder(&self) -> Option<Arc<TraceRecorder>> {
        self.recorder.clone()
    }

    /// Process one formed batch end to end.
    pub fn process(&self, batch: FormedBatch, metrics: &Metrics) {
        let t0 = std::time::Instant::now();
        let tier = batch.tier();
        // the admission-pressure signal, captured before parts move
        // out: the batch's OWN tier queue — using the hottest queue
        // across tiers here is how a Throughput flood used to degrade
        // Balanced (the cross-tier coupling bug)
        let occupancy = batch.tier_occupancy();
        let budget = match &self.controller {
            Some(ctl) => ctl.budget_for(tier).min(self.pool.len()).max(1),
            None => self.pool.len(),
        };
        // the tier's per-layer budget plan (plan-aware replication
        // workers truncate each layer's Eq. 3 grid to its entry);
        // full when no controller is attached
        let plan = Arc::new(match &self.controller {
            Some(ctl) => ctl.plan_for(tier),
            None => BudgetPlan::full(),
        });
        let planned_grid = plan.total_grid_terms();
        let anytime_tol = self
            .controller
            .as_ref()
            .filter(|ctl| ctl.config().anytime)
            .and_then(|ctl| ctl.batch_tolerance([tier]));
        let out_gain = match &self.tier_gains {
            Some(g) => g[tier.idx()],
            None => 1.0,
        };
        // streamed parts: (row offset, rows, trace id, sink), captured
        // before `batch.parts` moves into the reply scatter
        let mut streams: Vec<(usize, usize, u64, RefineSink)> = Vec::new();
        {
            let mut row = 0usize;
            for p in &batch.parts {
                if let Some(s) = &p.refine {
                    streams.push((row, p.rows, p.trace_id, s.clone()));
                }
                row += p.rows;
            }
        }
        let all_streamed = !streams.is_empty() && streams.len() == batch.parts.len();
        let frames_emitted: Vec<std::cell::Cell<usize>> =
            streams.iter().map(|_| std::cell::Cell::new(0)).collect();
        let tol = if streams.is_empty() {
            anytime_tol
        } else {
            // a refine-carrying batch must ride the sequential fold —
            // the tree reduction's grouping differs bitwise from the
            // frame stream's left fold — and tol = 0.0 never trips the
            // early stop, so streaming without an anytime controller
            // still consumes the full tier budget
            Some(anytime_tol.unwrap_or(0.0))
        };
        let on_term = |terms_after: usize, term: &Tensor| {
            let cols = term.dims()[1];
            for (k, (row, rows, trace_id, sink)) in streams.iter().enumerate() {
                if sink.cancelled() {
                    continue;
                }
                let data = term.data()[row * cols..(row + rows) * cols].to_vec();
                frames_emitted[k].set(frames_emitted[k].get() + 1);
                (sink.emit)(StreamFrame {
                    trace_id: *trace_id,
                    terms: terms_after,
                    rows: *rows,
                    cols,
                    data,
                    first: terms_after == 1,
                });
            }
        };
        // refinement stops early on cancel only when EVERY part of the
        // batch asked for it: co-batched requests still deserve their
        // full term budget
        let cancelled = || all_streamed && streams.iter().all(|(_, _, _, s)| s.cancelled());
        // queue-wait, batch-formation and schedule spans — one per
        // request, recorded BEFORE execution so even a failing batch
        // leaves every request with a closed chain up to the reduction
        if let Some(rec) = &self.recorder {
            let formed = rec.ns_of(batch.formed_at);
            let picked = rec.ns_of(t0);
            let sched_end = rec.now_ns();
            let depth = batch.tier_depths[tier.idx()] as u64;
            let rows = batch.x.dims()[0] as u64;
            let parts = batch.parts.len() as u64;
            let planned = planned_grid.unwrap_or(0) as u64;
            for p in &batch.parts {
                let enq = rec.ns_of(p.enqueued_at);
                let id = p.trace_id;
                let wait = [depth, 0, 0];
                rec.record_span(id, SpanKind::QueueWait, tier, false, enq, formed, wait);
                let form = [rows, parts, 0];
                rec.record_span(id, SpanKind::BatchForm, tier, false, formed, picked, form);
                let sched = [budget as u64, planned, 0];
                rec.record_span(id, SpanKind::Schedule, tier, false, picked, sched_end, sched);
            }
        }
        let ctx = self.recorder.as_ref().map(|rec| SpanCtx {
            recorder: rec.clone(),
            trace_ids: Arc::new(batch.parts.iter().map(|p| p.trace_id).collect()),
            tier,
        });
        let reduce_t0 = self.recorder.as_ref().map(|rec| rec.now_ns());
        let hooks = if streams.is_empty() {
            None
        } else {
            Some(StreamHooks { on_term: &on_term, cancelled: &cancelled })
        };
        let result = self.reduce_prefix(batch.x.clone(), budget, plan, tol, out_gain, ctx, hooks);
        // the reduce span closes for every request, error-flagged when
        // the batch failed — traces never show half-open timelines
        if let Some(rec) = &self.recorder {
            let t_end = rec.now_ns();
            let t_start = reduce_t0.unwrap_or(t_end);
            let (err, terms, grid) = match &result {
                Ok(r) => (false, r.terms as u64, r.grid_terms as u64),
                Err(_) => (true, 0, 0),
            };
            let detail = [terms, grid, 0];
            for p in &batch.parts {
                rec.record_span(p.trace_id, SpanKind::Reduce, tier, err, t_start, t_end, detail);
            }
            // one refine span per streamed part: terms consumed and
            // frames actually emitted to that part's sink
            for (k, (_, _, trace_id, _)) in streams.iter().enumerate() {
                let detail = [terms, frames_emitted[k].get() as u64, 0];
                rec.record_span(*trace_id, SpanKind::Refine, tier, err, t_start, t_end, detail);
            }
        }
        match result {
            Ok(reduced) => {
                let terms_used = reduced.terms;
                let logits = reduced.y;
                let est_loss = self
                    .controller
                    .as_ref()
                    .and_then(|ctl| ctl.estimated_loss(terms_used));
                // the batch forward is shared by every request in it:
                // grid spend is a batch-level observable, recorded once
                // (and BEFORE replies, so callers can assert on it),
                // alongside the plan ceiling it was served under
                metrics.record_batch_grid(tier, reduced.grid_terms, planned_grid);
                let mut row = 0usize;
                let classes = logits.dims()[1];
                for p in batch.parts {
                    let data = logits.data()[row * classes..(row + p.rows) * classes].to_vec();
                    row += p.rows;
                    // record BEFORE sending: the caller may assert on the
                    // metrics immediately after receiving the reply
                    let latency = p.enqueued_at.elapsed().as_secs_f64();
                    metrics.record_completed_tier(p.tier, latency, terms_used, est_loss);
                    if let Some(ctl) = &self.controller {
                        // the controller's windowed p99 digest sees
                        // exactly the latencies the metrics see
                        ctl.record_latency(p.tier, latency);
                    }
                    p.reply.send(Response {
                        id: p.id,
                        trace_id: p.trace_id,
                        logits: Tensor::from_vec(&[p.rows, classes], data),
                        latency_s: latency,
                        tier: p.tier,
                        terms: terms_used,
                        grid_terms: reduced.grid_terms,
                        error: None,
                    });
                }
                let service = t0.elapsed().as_secs_f64();
                metrics.record_batch(batch.x.dims()[0], service);
                // exactly one pressure decision per formed batch, for
                // the batch's own tier: consume the tier's latency
                // window and fold in this batch's service time
                if let Some(ctl) = &self.controller {
                    let p99 = ctl.take_tier_p99(tier);
                    ctl.observe_batch(tier, occupancy, Some(service), p99);
                }
            }
            Err(e) => {
                let msg = format!("{e:#}");
                log::error!("batch failed: {msg}");
                // tier-attributed failure counts: the exposition breaks
                // failures out per tier, not just in aggregate
                metrics.record_failed_tier(tier, batch.parts.len());
                // explicit error replies: TCP clients get an error frame
                // instead of hanging until RecvError
                for p in batch.parts {
                    let latency = p.enqueued_at.elapsed().as_secs_f64();
                    p.reply
                        .send(Response::failure(p.id, p.trace_id, p.tier, latency, msg.clone()));
                }
                if let Some(ctl) = &self.controller {
                    // a failed forward still relieves the tier's queue
                    // signal, but its service time stays out of the
                    // EWMA (and nothing entered the p99 digest): errors
                    // are fast, counting them would read as headroom
                    // and errors must not masquerade as load either way
                    let p99 = ctl.take_tier_p99(tier);
                    ctl.observe_batch(tier, occupancy, None, p99);
                }
            }
        }
    }

    /// The core forward: broadcast → (gain ∘ output) → AbelianAdd tree
    /// over the full pool.
    pub fn forward(&self, x: Tensor) -> anyhow::Result<Tensor> {
        let n = self.pool.len();
        Ok(self.reduce_prefix(x, n, Arc::new(BudgetPlan::full()), None, 1.0, None, None)?.y)
    }

    /// Truncated forward: reduce only the first `n` basis outputs.
    pub fn forward_truncated(&self, x: Tensor, n: usize) -> anyhow::Result<Tensor> {
        Ok(self.reduce_prefix(x, n, Arc::new(BudgetPlan::full()), None, 1.0, None, None)?.y)
    }

    /// Anytime forward over the first `n` workers: stream terms in
    /// series order and stop once the marginal term's max contribution
    /// falls below `tol` *relative to the leading term's magnitude*
    /// (scale-invariant, so small-magnitude activations do not trip the
    /// stop rule spuriously). Returns the reduction and terms consumed.
    pub fn forward_anytime(
        &self,
        x: Tensor,
        n: usize,
        tol: f32,
    ) -> anyhow::Result<(Tensor, usize)> {
        let plan = Arc::new(BudgetPlan::full());
        let r = self.reduce_prefix(x, n, plan, Some(tol), 1.0, None, None)?;
        Ok((r.y, r.terms))
    }

    /// Reduce the first `n` basis outputs (with gains applied), each
    /// worker running under `plan`. Without a tolerance,
    /// broadcast to all `n` workers in parallel and reduce as a
    /// balanced tree. With a tolerance, **stream** with a one-term
    /// lookahead pipeline: while term `i` is being inspected (gain,
    /// threshold check, add), term `i+1` is already in flight — the
    /// early stop then wastes at most ONE speculative worker run, while
    /// a hit recovers the dispatch/compute overlap the strictly serial
    /// stream gave up (PR 2 dispatched one term at a time, fully
    /// serializing term latency when the stop never triggered).
    /// `out_gain` is the tier's output scalar. On the tree path it is
    /// applied once to the reduced output (bit-identical to the old
    /// post-reduction scale). On the streamed path it is applied
    /// per-term *inside* the fold, so the emitted refinement frames
    /// ⊎-sum bit-identically to the final reply.
    #[allow(clippy::too_many_arguments)]
    fn reduce_prefix(
        &self,
        x: Tensor,
        n: usize,
        plan: Arc<BudgetPlan>,
        tol: Option<f32>,
        out_gain: f32,
        ctx: Option<SpanCtx>,
        hooks: Option<StreamHooks<'_>>,
    ) -> anyhow::Result<Reduced> {
        match tol {
            None => {
                let runs = self.pool.broadcast_runs_traced(x, n, plan, ctx)?;
                let mut grid_terms = 0usize;
                let outs: Vec<Tensor> = runs
                    .into_iter()
                    .enumerate()
                    .map(|(i, r)| {
                        grid_terms += r.grid_terms;
                        match &self.gains {
                            Some(g) => r.y.scale(g[i]),
                            None => r.y,
                        }
                    })
                    .collect();
                let terms = outs.len();
                let y = abelian_reduce(outs)
                    .ok_or_else(|| anyhow::anyhow!("empty worker pool"))?;
                let y = if out_gain != 1.0 { y.scale(out_gain) } else { y };
                Ok(Reduced { y, terms, grid_terms })
            }
            Some(tol) => {
                anyhow::ensure!(n >= 1, "anytime reduction needs at least one term");
                anyhow::ensure!(
                    n <= self.pool.len(),
                    "prefix {n} exceeds pool {}",
                    self.pool.len()
                );
                let x = Arc::new(x);
                let gained = |y: Tensor, i: usize| {
                    let y = match &self.gains {
                        Some(g) => y.scale(g[i]),
                        None => y,
                    };
                    if out_gain != 1.0 {
                        y.scale(out_gain)
                    } else {
                        y
                    }
                };
                let recv_run = |rx: super::pool::RunReceiver| {
                    let (_, res) =
                        rx.recv().map_err(|_| anyhow::anyhow!("worker output lost"))?;
                    res
                };
                // term 0 is always consumed and sets the stop threshold;
                // its lookahead (term 1) is dispatched before we block.
                // Only the head dispatch carries the span context: a
                // speculative lookahead abandoned by the early stop
                // would record a worker span that outlives the reduce
                // span and breaks nesting, so streamed-anytime traces
                // carry one worker span (the always-consumed head term)
                // and leave full term/grid accounting to the reduce
                // span's detail
                let head = self.pool.dispatch_one_traced(0, x.clone(), plan.clone(), ctx)?;
                let mut pending = if n > 1 {
                    Some(self.pool.dispatch_one(1, x.clone(), plan.clone())?)
                } else {
                    None
                };
                let run = recv_run(head)?;
                let mut grid_terms = run.grid_terms;
                let mut acc = gained(run.y, 0);
                // the head term IS the immediate truncated-prefix answer
                if let Some(h) = &hooks {
                    (h.on_term)(1, &acc);
                }
                // relative threshold: tolerance × leading-term magnitude,
                // invariant to the input's scale
                let threshold = tol * acc.max_abs();
                let mut terms = 1usize;
                for i in 1..n {
                    // a client cancel stops refinement between terms;
                    // the in-flight lookahead is the bounded waste,
                    // exactly as for the tolerance early-stop below
                    if let Some(h) = &hooks {
                        if (h.cancelled)() {
                            break;
                        }
                    }
                    // one-term lookahead: exactly one dispatch in flight
                    // beyond the term currently being inspected
                    let lookahead = if i + 1 < n {
                        Some(self.pool.dispatch_one(i + 1, x.clone(), plan.clone())?)
                    } else {
                        None
                    };
                    let rx = pending.take().expect("lookahead dispatched for term");
                    let run = recv_run(rx)?;
                    grid_terms += run.grid_terms;
                    let term = gained(run.y, i);
                    // the series' geometric scale law makes later terms
                    // strictly smaller; once one drops below the batch
                    // tolerance the tail is negligible. The already-sent
                    // lookahead is the bounded waste: its receiver drops
                    // here (never awaited — waiting would forfeit the
                    // early stop's latency win) and its grid spend is
                    // deliberately NOT counted, so `grid_terms` meters
                    // the compute reduced into the answer.
                    if term.max_abs() < threshold {
                        break;
                    }
                    acc = acc.add(&term);
                    terms += 1;
                    // emit AFTER the threshold check and the add: a
                    // frame always represents a term that is reduced
                    // into the final answer
                    if let Some(h) = &hooks {
                        (h.on_term)(terms, &term);
                    }
                    match lookahead {
                        Some(rx) => pending = Some(rx),
                        None => break,
                    }
                }
                Ok(Reduced { y: acc, terms, grid_terms })
            }
        }
    }

    pub fn shutdown(self) {
        self.pool.shutdown();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::pool::BasisWorker;
    use crate::qos::{QosConfig, Tier};
    use std::sync::Arc;

    struct Id;
    impl BasisWorker for Id {
        fn run(&mut self, x: &Tensor) -> anyhow::Result<Tensor> {
            Ok(x.clone())
        }
    }

    fn id_pool(n: usize) -> WorkerPool {
        WorkerPool::new(n, Arc::new(|_| Box::new(Id) as Box<dyn BasisWorker>))
    }

    #[test]
    fn gains_apply_abelian_mul() {
        let sched = ExpansionScheduler::new(id_pool(3)).with_gains(vec![1.0, 0.5, 0.25]);
        let y = sched.forward(Tensor::vec1(&[8.0]).reshaped(&[1, 1])).unwrap();
        assert!((y.data()[0] - 14.0).abs() < 1e-5); // 8·(1+0.5+0.25)
        sched.shutdown();
    }

    #[test]
    fn truncated_forward_reduces_prefix_only() {
        let sched = ExpansionScheduler::new(id_pool(4)).with_gains(vec![1.0, 0.5, 0.25, 0.125]);
        let x = Tensor::vec1(&[8.0]).reshaped(&[1, 1]);
        let y2 = sched.forward_truncated(x.clone(), 2).unwrap();
        assert!((y2.data()[0] - 12.0).abs() < 1e-5); // 8·(1+0.5)
        let y4 = sched.forward_truncated(x, 4).unwrap();
        assert!((y4.data()[0] - 15.0).abs() < 1e-5);
        sched.shutdown();
    }

    #[test]
    fn anytime_stops_when_marginal_below_tol() {
        // gains shrink geometrically: terms contribute 8, 4, 2, 1;
        // tol is relative to the leading term (threshold = 0.2·8 = 1.6)
        let sched =
            ExpansionScheduler::new(id_pool(4)).with_gains(vec![1.0, 0.5, 0.25, 0.125]);
        let x = Tensor::vec1(&[8.0]).reshaped(&[1, 1]);
        let (y, terms) = sched.forward_anytime(x.clone(), 4, 0.2).unwrap();
        // stops before the 4th term (contribution 1 < 1.6)
        assert_eq!(terms, 3);
        assert!((y.data()[0] - 14.0).abs() < 1e-5);
        // the stop rule is scale-invariant: a 1000× smaller input stops
        // at the same term count
        let (_, terms_small) =
            sched.forward_anytime(x.scale(1e-3), 4, 0.2).unwrap();
        assert_eq!(terms_small, 3);
        // a zero tolerance consumes everything
        let (_, all) = sched.forward_anytime(x, 4, 0.0).unwrap();
        assert_eq!(all, 4);
        sched.shutdown();
    }

    #[test]
    fn anytime_streams_with_one_term_lookahead_bounded_waste() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        struct CountingId {
            calls: Arc<[AtomicUsize; 6]>,
            i: usize,
        }
        impl BasisWorker for CountingId {
            fn run(&mut self, x: &Tensor) -> anyhow::Result<Tensor> {
                self.calls[self.i].fetch_add(1, Ordering::SeqCst);
                Ok(x.clone())
            }
        }
        let calls: Arc<[AtomicUsize; 6]> =
            Arc::new(std::array::from_fn(|_| AtomicUsize::new(0)));
        let c2 = calls.clone();
        let pool = WorkerPool::new(
            6,
            Arc::new(move |i| {
                Box::new(CountingId { calls: c2.clone(), i }) as Box<dyn BasisWorker>
            }),
        );
        let sched = ExpansionScheduler::new(pool)
            .with_gains(vec![1.0, 0.5, 0.25, 0.125, 0.0625, 0.03125]);
        let x = Tensor::vec1(&[8.0]).reshaped(&[1, 1]);
        // contributions 8, 4, 2, 1, …; threshold 0.2·8 = 1.6 → term 4
        // runs to reveal the stop, term 5 was the one-term-lookahead
        // speculation already in flight, term 6 never dispatches
        let (y, terms) = sched.forward_anytime(x, 6, 0.2).unwrap();
        assert_eq!(terms, 3);
        assert!((y.data()[0] - 14.0).abs() < 1e-5);
        // shutdown drains every dispatched job, so the counts are final
        sched.shutdown();
        let counts: Vec<usize> = calls.iter().map(|c| c.load(Ordering::SeqCst)).collect();
        assert_eq!(counts[..4], [1, 1, 1, 1], "{counts:?}");
        assert_eq!(
            counts[4], 1,
            "the lookahead speculates exactly one worker past the stop: {counts:?}"
        );
        assert_eq!(counts[5], 0, "beyond the lookahead no worker may run: {counts:?}");
    }

    #[test]
    fn controller_budget_truncates_batch_processing() {
        use crate::coordinator::{BatcherConfig, Coordinator};
        let ctl = Arc::new(TermController::new(QosConfig::new(4)));
        let sched = ExpansionScheduler::new(id_pool(4))
            .with_gains(vec![1.0, 0.5, 0.25, 0.125])
            .with_controller(ctl.clone());
        let coord = Coordinator::new(BatcherConfig::uniform(8, 200, 32), sched);
        let x = Tensor::vec1(&[8.0]).reshaped(&[1, 1]);
        // Exact: all four terms
        let r = coord.infer_tier(x.clone(), Tier::Exact).unwrap();
        assert_eq!(r.terms, 4);
        assert!((r.logits.data()[0] - 15.0).abs() < 1e-5);
        // BestEffort default budget is 1 term
        let r = coord.infer_tier(x, Tier::BestEffort).unwrap();
        assert_eq!(r.terms, 1);
        assert!((r.logits.data()[0] - 8.0).abs() < 1e-5);
        assert_eq!(coord.metrics.tier_completed(Tier::BestEffort), 1);
        assert!(coord.metrics.tier_mean_terms(Tier::BestEffort) < 2.0);
        coord.shutdown();
    }

    #[test]
    fn tier_gains_scale_reduced_output() {
        use crate::coordinator::{BatcherConfig, Coordinator};
        let mut tg = [1.0f32; NUM_TIERS];
        tg[Tier::BestEffort.idx()] = 2.0;
        let sched = ExpansionScheduler::new(id_pool(2)).with_tier_gains(tg);
        let coord = Coordinator::new(BatcherConfig::uniform(4, 200, 16), sched);
        let x = Tensor::vec1(&[3.0]).reshaped(&[1, 1]);
        let exact = coord.infer_tier(x.clone(), Tier::Exact).unwrap();
        assert!((exact.logits.data()[0] - 6.0).abs() < 1e-5);
        let be = coord.infer_tier(x, Tier::BestEffort).unwrap();
        assert!((be.logits.data()[0] - 12.0).abs() < 1e-5);
        coord.shutdown();
    }

    #[test]
    fn failed_batches_never_pollute_the_pressure_signal() {
        use crate::coordinator::{BatcherConfig, Coordinator};
        struct Failing;
        impl BasisWorker for Failing {
            fn run(&mut self, _x: &Tensor) -> anyhow::Result<Tensor> {
                anyhow::bail!("injected basis failure")
            }
        }
        // a hair-trigger service target: ONE polluting service sample
        // from the error path would step pressure immediately
        let qcfg = QosConfig::new(1).with_service_target(1e-12);
        let ctl = Arc::new(TermController::new(qcfg));
        let pool = WorkerPool::new(1, Arc::new(|_| Box::new(Failing) as Box<dyn BasisWorker>));
        let coord = Coordinator::new(
            BatcherConfig::uniform(2, 100, 8),
            ExpansionScheduler::new(pool).with_controller(ctl.clone()),
        );
        // pre-heat Balanced so the error path's occupancy RELIEF is
        // observable too (failures drain queues; that part must count)
        ctl.observe_batch(Tier::Balanced, 0.95, None, None);
        assert_eq!(ctl.tier_pressure(Tier::Balanced), 1);
        for _ in 0..3 {
            assert!(coord.infer_tier(Tensor::zeros(&[1, 2]), Tier::Balanced).is_err());
        }
        // shutdown joins the forming thread, so every batch's pressure
        // decision has landed before the asserts
        coord.shutdown();
        assert_eq!(
            ctl.tier_service_ewma(Tier::Balanced),
            None,
            "a failed forward's service time leaked into the EWMA"
        );
        let p99 = ctl.tier_p99(Tier::Balanced);
        assert_eq!(p99, None, "failed replies must not enter the digest");
        assert_eq!(
            ctl.tier_pressure(Tier::Balanced),
            0,
            "failed batches at an empty queue must relieve, never heat"
        );
        assert_eq!(ctl.snapshot().tier_degrade_events[Tier::Balanced.idx()], 1);
    }

    #[test]
    fn failed_batch_sends_error_response() {
        use crate::coordinator::{BatcherConfig, Coordinator};
        struct Failing;
        impl BasisWorker for Failing {
            fn run(&mut self, _x: &Tensor) -> anyhow::Result<Tensor> {
                anyhow::bail!("injected basis failure")
            }
        }
        let pool = WorkerPool::new(1, Arc::new(|_| Box::new(Failing) as Box<dyn BasisWorker>));
        let coord = Coordinator::new(
            BatcherConfig::uniform(2, 100, 8),
            ExpansionScheduler::new(pool),
        );
        let rx = coord.submit(Tensor::zeros(&[1, 2])).unwrap();
        let resp = rx.recv_timeout(std::time::Duration::from_secs(10)).unwrap();
        let err = resp.error.expect("explicit error reply");
        assert!(err.contains("injected basis failure"), "{err}");
        assert_eq!(coord.metrics.failed(), 1);
        // infer() surfaces the same failure as Err
        assert!(coord.infer(Tensor::zeros(&[1, 2])).is_err());
        coord.shutdown();
    }
}
