//! L3 coordinator — the serving system around the paper's expansion:
//! request routing, dynamic batching, basis-model scheduling across a
//! worker pool, and the AbelianAdd AllReduce that recombines basis
//! outputs (Theorem 2's deployment shape: t·k low-bit models run in
//! parallel, one commutative reduction at the end).
//!
//! * [`pool`] — worker threads; each owns one basis model (optionally a
//!   per-thread PJRT runtime — `xla::PjRtClient` is not `Send`).
//! * [`batcher`] — one bounded queue per tier served by weighted
//!   deficit round-robin (tier-grouped forming, per-tier admission
//!   control with shed accounting) and per-tier queue-depth export for
//!   the QoS pressure signal.
//! * [`scheduler`] — broadcast/collect over the pool + AbelianAdd tree,
//!   with tier-truncated prefix reduction and anytime early stopping
//!   (see [`crate::qos`]).
//! * [`metrics`] — counters and latency summaries, per tier.

pub mod batcher;
pub mod metrics;
pub mod pool;
pub mod scheduler;

pub use batcher::{Batcher, BatcherConfig, ServicePolicy, SubmitError};
pub use metrics::Metrics;
pub use pool::{BasisWorker, BudgetedRun, WorkerPool};
pub use scheduler::ExpansionScheduler;

use crate::obs::{chrome_trace_json, ExpositionBuilder, SpanKind, TraceRecorder};
use crate::qos::{TermController, Tier};
use crate::tensor::Tensor;
use crate::util::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use crate::util::sync::{mpsc, Arc};

/// Where a [`Response`] is delivered. Blocking callers hold the
/// receiving end of a channel; the reactor front-end registers a
/// callback instead (it cannot block a thread per request), which runs
/// on the batcher's forming thread and must therefore only enqueue and
/// wake — never block.
pub enum ReplySink {
    Channel(mpsc::Sender<Response>),
    Callback(Arc<dyn Fn(Response) + Send + Sync>),
}

impl ReplySink {
    /// Deliver the reply. A dropped channel receiver is not an error —
    /// the caller gave up waiting, matching mpsc semantics.
    pub fn send(&self, r: Response) {
        match self {
            ReplySink::Channel(tx) => {
                let _ = tx.send(r);
            }
            ReplySink::Callback(f) => f(r),
        }
    }
}

/// One progressive-refinement emission: the gained contribution of a
/// single consumed series term, sliced to one request's rows. The ⊎-sum
/// of a request's frames (in emission order) is bit-identical to the
/// logits of its final [`Response`], because both are produced by the
/// same sequential left-fold reduction.
pub struct StreamFrame {
    pub trace_id: u64,
    /// cumulative terms reduced once this frame is applied
    pub terms: usize,
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
    /// true for the first (truncated-prefix) frame of a stream
    pub first: bool,
}

/// Progressive-refinement hooks a streamed request carries through the
/// batcher into the scheduler's anytime reduction.
#[derive(Clone)]
pub struct RefineSink {
    /// called once per consumed term with that request's slice; runs on
    /// the batcher thread, so it must only enqueue and wake
    pub emit: Arc<dyn Fn(StreamFrame) + Send + Sync>,
    /// client-cancel flag (set by the reactor on a cancel frame)
    pub cancel: Arc<AtomicBool>,
}

impl RefineSink {
    pub fn cancelled(&self) -> bool {
        // ordering: Relaxed — lone advisory stop flag polled by the
        // refinement loop; nothing is published through it, so
        // atomicity alone is the contract.
        self.cancel.load(Ordering::Relaxed)
    }
}

/// One inference request: a (n, din) batch of samples, its service
/// tier, a trace correlation id, and a reply slot.
pub struct Request {
    pub id: u64,
    /// request-scoped trace id threaded through every pipeline span and
    /// echoed in the [`Response`] (and the TCP frame)
    pub trace_id: u64,
    pub x: Tensor,
    pub tier: Tier,
    pub reply: ReplySink,
    /// progressive-refinement sink for streamed (protocol v3) requests
    pub refine: Option<RefineSink>,
}

/// The reply: logits for the request's samples, plus how the request
/// was actually served (tier, basis terms reduced). `error` is set when
/// the owning batch failed — the logits are then empty and callers must
/// surface the message instead of hanging on a dropped channel.
#[derive(Clone, Debug)]
pub struct Response {
    pub id: u64,
    /// the request's trace correlation id, echoed back so callers can
    /// join their reply onto the flight recorder's spans
    pub trace_id: u64,
    pub logits: Tensor,
    /// end-to-end latency attributed by the coordinator
    pub latency_s: f64,
    /// tier the request was served under
    pub tier: Tier,
    /// number of series terms reduced into `logits`
    pub terms: usize,
    /// INT GEMM `(i, j)` grid terms executed by budget-aware workers
    /// and *reduced into this reply* — a batch-level observable (the
    /// batch forward is shared by its requests). 0 when the backend
    /// doesn't meter grids. In anytime mode a discarded speculative
    /// lookahead run is not counted: this meters the compute behind the
    /// answer, not total compute burned.
    pub grid_terms: usize,
    /// protocol-level failure carried to the caller (batch error)
    pub error: Option<String>,
}

impl Response {
    /// A failed reply: empty logits, explicit error message.
    pub fn failure(id: u64, trace_id: u64, tier: Tier, latency_s: f64, msg: String) -> Response {
        Response {
            id,
            trace_id,
            logits: Tensor::zeros(&[0, 0]),
            latency_s,
            tier,
            terms: 0,
            grid_terms: 0,
            error: Some(msg),
        }
    }
}

/// The assembled serving coordinator: batcher → scheduler → AllReduce.
pub struct Coordinator {
    batcher: Batcher,
    pub metrics: Arc<Metrics>,
    /// QoS controller attached to the scheduler, if any — an
    /// observability handle so the serving layer (TCP front-end,
    /// examples, benches) can surface per-tier pressure next to
    /// shed/queue stats. `None` when serving without a control plane.
    pub qos: Option<Arc<TermController>>,
    /// Flight recorder attached to the scheduler
    /// ([`ExpansionScheduler::with_recorder`]), if any — the serving
    /// layer dumps it as a Chrome trace and counts its drops in the
    /// metrics exposition. `None` = tracing off, zero overhead.
    pub recorder: Option<Arc<TraceRecorder>>,
    /// trace ids handed out when the caller didn't bring one (0 is
    /// reserved as "assign for me", so the counter starts at 1)
    next_trace: AtomicU64,
}

impl Coordinator {
    /// Build with `scheduler` handling each formed batch.
    pub fn new(cfg: BatcherConfig, scheduler: ExpansionScheduler) -> Coordinator {
        let metrics = Arc::new(Metrics::new());
        let m2 = metrics.clone();
        let qos = scheduler.controller();
        let recorder = scheduler.recorder();
        let batcher = Batcher::start(cfg, move |batch| scheduler.process(batch, &m2));
        Coordinator { batcher, metrics, qos, recorder, next_trace: AtomicU64::new(1) }
    }

    /// A fresh coordinator-assigned trace id (never 0 — the wire
    /// protocol reserves 0 for "server assigns").
    pub fn fresh_trace_id(&self) -> u64 {
        // ordering: Relaxed — id allocation only needs RMW uniqueness;
        // nothing is published under the counter.
        self.next_trace.fetch_add(1, Ordering::Relaxed)
    }

    /// Submit a request at [`Tier::Exact`] (non-blocking; sheds when the
    /// queue is full).
    pub fn submit(&self, x: Tensor) -> Result<mpsc::Receiver<Response>, SubmitError> {
        self.submit_tier(x, Tier::Exact)
    }

    /// Submit a request at an explicit service tier.
    pub fn submit_tier(
        &self,
        x: Tensor,
        tier: Tier,
    ) -> Result<mpsc::Receiver<Response>, SubmitError> {
        let trace_id = self.fresh_trace_id();
        self.submit_tier_traced(x, tier, trace_id)
    }

    /// [`Coordinator::submit_tier`] under a caller-supplied trace id
    /// (must be nonzero). Records the admission span — error-flagged on
    /// a shed, so even rejected requests leave a closed trace.
    pub fn submit_tier_traced(
        &self,
        x: Tensor,
        tier: Tier,
        trace_id: u64,
    ) -> Result<mpsc::Receiver<Response>, SubmitError> {
        let rec = match &self.recorder {
            None => return self.batcher.submit_traced(x, tier, trace_id),
            Some(rec) => rec,
        };
        let t0 = rec.now_ns();
        let depth = self.batcher.tier_depth(tier) as u64;
        let res = self.batcher.submit_traced(x, tier, trace_id);
        let shed = res.is_err();
        rec.record_span(trace_id, SpanKind::Admission, tier, shed, t0, rec.now_ns(), [depth, 0, 0]);
        res
    }

    /// Callback submission for the reactor front-end: the reply is
    /// delivered through `sink` (and refinement frames through
    /// `refine`, for streamed requests) instead of a channel, so no
    /// thread blocks per in-flight request. Records the admission span
    /// exactly like [`Coordinator::submit_tier_traced`].
    pub fn submit_tier_callback(
        &self,
        x: Tensor,
        tier: Tier,
        trace_id: u64,
        sink: ReplySink,
        refine: Option<RefineSink>,
    ) -> Result<(), SubmitError> {
        let rec = match &self.recorder {
            None => return self.batcher.submit_with_sink(x, tier, trace_id, sink, refine),
            Some(rec) => rec,
        };
        let t0 = rec.now_ns();
        let depth = self.batcher.tier_depth(tier) as u64;
        let res = self.batcher.submit_with_sink(x, tier, trace_id, sink, refine);
        let shed = res.is_err();
        rec.record_span(trace_id, SpanKind::Admission, tier, shed, t0, rec.now_ns(), [depth, 0, 0]);
        res
    }

    /// Count a shed decided outside the batcher's own admission check —
    /// the reactor's write-backpressure shed — in `tier`'s statistics,
    /// so the exposition reflects every `CODE_SHED` frame on the wire.
    pub fn record_shed(&self, tier: Tier) {
        self.batcher.record_shed(tier);
    }

    /// Submit and wait for the reply; a batch failure surfaces as `Err`.
    pub fn infer(&self, x: Tensor) -> anyhow::Result<Response> {
        self.infer_tier(x, Tier::Exact)
    }

    /// Submit at `tier` and wait for the reply.
    pub fn infer_tier(&self, x: Tensor, tier: Tier) -> anyhow::Result<Response> {
        let rx = self
            .submit_tier(x, tier)
            .map_err(|e| anyhow::anyhow!("submit failed: {e:?}"))?;
        let resp = rx.recv()?;
        match resp.error {
            Some(msg) => Err(anyhow::anyhow!("batch failed: {msg}")),
            None => Ok(resp),
        }
    }

    /// Current batcher queue depth across all tiers (requests accepted,
    /// not yet formed into a batch).
    pub fn queue_depth(&self) -> usize {
        self.batcher.queue_depth()
    }

    /// One tier's queue depth — the per-tier QoS pressure signal.
    pub fn tier_depth(&self, tier: Tier) -> usize {
        self.batcher.tier_depth(tier)
    }

    /// Requests shed at one tier's admission check since start.
    pub fn tier_shed(&self, tier: Tier) -> u64 {
        self.batcher.shed_count(tier)
    }

    /// Render the Prometheus-style text exposition of the serving
    /// plane: per-tier request/failure/shed counters, queue depths,
    /// latency histograms, term/grid/est-loss gauges, the QoS
    /// controller's pressure and degrade/restore counters (when
    /// attached), and flight-recorder volume (when tracing is on).
    pub fn exposition(&self) -> String {
        let m = &self.metrics;
        let mut b = ExpositionBuilder::new();
        let per_tier = |b: &mut ExpositionBuilder,
                        name: &str,
                        kind: &str,
                        help: &str,
                        value: &dyn Fn(Tier) -> f64| {
            b.family(name, kind, help);
            for t in Tier::ALL {
                b.series(name, &[("tier", t.name())], value(t));
            }
        };
        per_tier(
            &mut b,
            "fpxint_requests_completed_total",
            "counter",
            "completed requests per tier",
            &|t| m.tier_completed(t) as f64,
        );
        per_tier(
            &mut b,
            "fpxint_requests_failed_total",
            "counter",
            "failed requests per tier (batch-execution errors)",
            &|t| m.tier_failed(t) as f64,
        );
        per_tier(
            &mut b,
            "fpxint_requests_shed_total",
            "counter",
            "requests shed at admission per tier (queue full)",
            &|t| self.tier_shed(t) as f64,
        );
        per_tier(
            &mut b,
            "fpxint_queue_depth",
            "gauge",
            "requests accepted but not yet batched, per tier",
            &|t| self.tier_depth(t) as f64,
        );
        b.family(
            "fpxint_request_latency_seconds",
            "histogram",
            "end-to-end request latency per tier (seconds)",
        );
        for t in Tier::ALL {
            b.histogram(
                "fpxint_request_latency_seconds",
                &[("tier", t.name())],
                &m.tier_latency_histogram(t),
            );
        }
        per_tier(
            &mut b,
            "fpxint_mean_terms",
            "gauge",
            "mean basis terms reduced per request, per tier",
            &|t| m.tier_mean_terms(t),
        );
        per_tier(
            &mut b,
            "fpxint_mean_grid_terms",
            "gauge",
            "mean executed Eq.3 grid terms per batch forward, per tier",
            &|t| m.tier_mean_grid_terms(t),
        );
        per_tier(
            &mut b,
            "fpxint_mean_planned_grid_terms",
            "gauge",
            "mean planned grid ceiling per plan-carrying batch, per tier",
            &|t| m.tier_mean_planned_grid_terms(t),
        );
        per_tier(
            &mut b,
            "fpxint_est_loss",
            "gauge",
            "worst estimated precision loss served, per tier",
            &|t| m.tier_est_loss(t),
        );
        b.family("fpxint_batches_total", "counter", "formed batches executed");
        b.series("fpxint_batches_total", &[], m.batches() as f64);
        b.family("fpxint_mean_batch_size", "gauge", "mean sample rows per formed batch");
        b.series("fpxint_mean_batch_size", &[], m.mean_batch_size());
        if let Some(ctl) = &self.qos {
            let snap = ctl.snapshot();
            per_tier(&mut b, "fpxint_tier_pressure", "gauge", "QoS pressure level per tier", &|t| {
                snap.pressures[t.idx()] as f64
            });
            per_tier(
                &mut b,
                "fpxint_tier_budget_terms",
                "gauge",
                "effective basis-term budget per tier",
                &|t| snap.budgets[t.idx()] as f64,
            );
            per_tier(
                &mut b,
                "fpxint_degrade_events_total",
                "counter",
                "pressure degrade steps per tier",
                &|t| snap.tier_degrade_events[t.idx()] as f64,
            );
            per_tier(
                &mut b,
                "fpxint_restore_events_total",
                "counter",
                "pressure restore steps per tier",
                &|t| snap.tier_restore_events[t.idx()] as f64,
            );
        }
        if let Some(rec) = &self.recorder {
            b.family(
                "fpxint_trace_events_recorded_total",
                "counter",
                "spans written to the flight recorder",
            );
            b.series("fpxint_trace_events_recorded_total", &[], rec.recorded() as f64);
            b.family(
                "fpxint_trace_events_dropped_total",
                "counter",
                "spans overwritten by ring wrap before export",
            );
            b.series("fpxint_trace_events_dropped_total", &[], rec.dropped() as f64);
        }
        b.finish()
    }

    /// Dump the flight recorder as Chrome-trace-event JSON (open in
    /// Perfetto / `chrome://tracing`). `[]` when tracing is off.
    pub fn trace_json(&self) -> String {
        match &self.recorder {
            Some(rec) => chrome_trace_json(&rec.events()).render(),
            None => "[]".to_string(),
        }
    }

    /// Drain and stop.
    pub fn shutdown(self) {
        self.batcher.shutdown();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Rng;

    /// A worker that computes `x * weight_scalar` — enough to validate
    /// the batching/reduction plumbing deterministically.
    struct ScalarWorker(f32);

    impl BasisWorker for ScalarWorker {
        fn run(&mut self, x: &Tensor) -> anyhow::Result<Tensor> {
            Ok(x.scale(self.0))
        }
    }

    fn scalar_coordinator(weights: Vec<f32>, max_batch: usize) -> Coordinator {
        let pool = WorkerPool::new(
            weights.len(),
            Arc::new(move |i: usize| {
                Box::new(ScalarWorker(weights[i])) as Box<dyn BasisWorker>
            }),
        );
        let sched = ExpansionScheduler::new(pool);
        Coordinator::new(BatcherConfig::uniform(max_batch, 500, 64), sched)
    }

    #[test]
    fn single_request_reduces_all_basis_outputs() {
        // Σ of 0.5x + 0.25x + 0.25x = x
        let c = scalar_coordinator(vec![0.5, 0.25, 0.25], 8);
        let mut rng = Rng::seed(31);
        let x = Tensor::randn(&[2, 4], 1.0, &mut rng);
        let resp = c.infer(x.clone()).unwrap();
        for (a, b) in x.data().iter().zip(resp.logits.data()) {
            assert!((a - b).abs() < 1e-5, "{a} vs {b}");
        }
        assert_eq!(resp.tier, Tier::Exact);
        assert_eq!(resp.terms, 3, "exact tier reduces the full pool");
        assert!(resp.error.is_none());
        assert_eq!(c.metrics.completed(), 1);
        c.shutdown();
    }

    #[test]
    fn many_concurrent_requests_all_answered() {
        let c = Arc::new(scalar_coordinator(vec![1.0, 2.0], 4));
        let mut handles = Vec::new();
        for t in 0..8 {
            let c = c.clone();
            handles.push(std::thread::spawn(move || {
                let mut rng = Rng::seed(100 + t);
                for _ in 0..5 {
                    let x = Tensor::randn(&[1, 3], 1.0, &mut rng);
                    let resp = c.infer(x.clone()).unwrap();
                    // workers sum to 3x
                    for (a, b) in x.data().iter().zip(resp.logits.data()) {
                        assert!((a * 3.0 - b).abs() < 1e-4);
                    }
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(c.metrics.completed(), 40);
    }

    #[test]
    fn batching_preserves_request_boundaries() {
        let c = scalar_coordinator(vec![2.0], 16);
        let mut rng = Rng::seed(9);
        // different-sized requests interleaved
        let xs: Vec<Tensor> = (1..=4).map(|n| Tensor::randn(&[n, 2], 1.0, &mut rng)).collect();
        let rxs: Vec<_> = xs.iter().map(|x| c.submit(x.clone()).unwrap()).collect();
        for (x, rx) in xs.iter().zip(rxs) {
            let resp = rx.recv().unwrap();
            assert_eq!(resp.logits.dims(), x.dims());
            for (a, b) in x.data().iter().zip(resp.logits.data()) {
                assert!((a * 2.0 - b).abs() < 1e-5);
            }
        }
        c.shutdown();
    }

    #[test]
    fn tiered_submit_reports_served_tier() {
        let c = scalar_coordinator(vec![0.5, 0.5], 8);
        let mut rng = Rng::seed(32);
        let x = Tensor::randn(&[1, 4], 1.0, &mut rng);
        // without a controller every tier runs the full pool; the tier
        // tag must still round-trip to the response
        let resp = c.infer_tier(x, Tier::Throughput).unwrap();
        assert_eq!(resp.tier, Tier::Throughput);
        assert_eq!(resp.terms, 2);
        assert_eq!(c.metrics.tier_completed(Tier::Throughput), 1);
        assert_eq!(c.metrics.tier_completed(Tier::Exact), 0);
        c.shutdown();
    }
}
