//! L3 coordinator — the serving system around the paper's expansion:
//! request routing, dynamic batching, basis-model scheduling across a
//! worker pool, and the AbelianAdd AllReduce that recombines basis
//! outputs (Theorem 2's deployment shape: t·k low-bit models run in
//! parallel, one commutative reduction at the end).
//!
//! * [`pool`] — worker threads; each owns one basis model (optionally a
//!   per-thread PJRT runtime — `xla::PjRtClient` is not `Send`).
//! * [`batcher`] — bounded request queue with timeout-based batch forming
//!   and shed-on-full backpressure.
//! * [`scheduler`] — broadcast/collect over the pool + AbelianAdd tree.
//! * [`metrics`] — counters and latency summaries for the benches.

pub mod batcher;
pub mod metrics;
pub mod pool;
pub mod scheduler;

pub use batcher::{Batcher, BatcherConfig, SubmitError};
pub use metrics::Metrics;
pub use pool::{BasisWorker, WorkerPool};
pub use scheduler::ExpansionScheduler;

use crate::tensor::Tensor;
use std::sync::mpsc;
use std::sync::Arc;

/// One inference request: a (n, din) batch of samples and a reply slot.
pub struct Request {
    pub id: u64,
    pub x: Tensor,
    pub reply: mpsc::Sender<Response>,
}

/// The reply: logits for the request's samples.
#[derive(Clone, Debug)]
pub struct Response {
    pub id: u64,
    pub logits: Tensor,
    /// end-to-end latency attributed by the coordinator
    pub latency_s: f64,
}

/// The assembled serving coordinator: batcher → scheduler → AllReduce.
pub struct Coordinator {
    batcher: Batcher,
    pub metrics: Arc<Metrics>,
}

impl Coordinator {
    /// Build with `scheduler` handling each formed batch.
    pub fn new(cfg: BatcherConfig, scheduler: ExpansionScheduler) -> Coordinator {
        let metrics = Arc::new(Metrics::new());
        let m2 = metrics.clone();
        let batcher = Batcher::start(cfg, move |batch| scheduler.process(batch, &m2));
        Coordinator { batcher, metrics }
    }

    /// Submit a request (non-blocking; sheds when the queue is full).
    pub fn submit(&self, x: Tensor) -> Result<mpsc::Receiver<Response>, SubmitError> {
        self.batcher.submit(x)
    }

    /// Submit and wait for the reply.
    pub fn infer(&self, x: Tensor) -> anyhow::Result<Response> {
        let rx = self
            .submit(x)
            .map_err(|e| anyhow::anyhow!("submit failed: {e:?}"))?;
        Ok(rx.recv()?)
    }

    /// Drain and stop.
    pub fn shutdown(self) {
        self.batcher.shutdown();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Rng;

    /// A worker that computes `x * weight_scalar` — enough to validate
    /// the batching/reduction plumbing deterministically.
    struct ScalarWorker(f32);

    impl BasisWorker for ScalarWorker {
        fn run(&mut self, x: &Tensor) -> anyhow::Result<Tensor> {
            Ok(x.scale(self.0))
        }
    }

    fn scalar_coordinator(weights: Vec<f32>, max_batch: usize) -> Coordinator {
        let pool = WorkerPool::new(
            weights.len(),
            Arc::new(move |i: usize| {
                Box::new(ScalarWorker(weights[i])) as Box<dyn BasisWorker>
            }),
        );
        let sched = ExpansionScheduler::new(pool);
        let cfg = BatcherConfig { max_batch, max_wait_us: 500, queue_cap: 64 };
        Coordinator::new(cfg, sched)
    }

    #[test]
    fn single_request_reduces_all_basis_outputs() {
        // Σ of 0.5x + 0.25x + 0.25x = x
        let c = scalar_coordinator(vec![0.5, 0.25, 0.25], 8);
        let mut rng = Rng::seed(31);
        let x = Tensor::randn(&[2, 4], 1.0, &mut rng);
        let resp = c.infer(x.clone()).unwrap();
        for (a, b) in x.data().iter().zip(resp.logits.data()) {
            assert!((a - b).abs() < 1e-5, "{a} vs {b}");
        }
        assert_eq!(c.metrics.completed(), 1);
        c.shutdown();
    }

    #[test]
    fn many_concurrent_requests_all_answered() {
        let c = Arc::new(scalar_coordinator(vec![1.0, 2.0], 4));
        let mut handles = Vec::new();
        for t in 0..8 {
            let c = c.clone();
            handles.push(std::thread::spawn(move || {
                let mut rng = Rng::seed(100 + t);
                for _ in 0..5 {
                    let x = Tensor::randn(&[1, 3], 1.0, &mut rng);
                    let resp = c.infer(x.clone()).unwrap();
                    // workers sum to 3x
                    for (a, b) in x.data().iter().zip(resp.logits.data()) {
                        assert!((a * 3.0 - b).abs() < 1e-4);
                    }
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(c.metrics.completed(), 40);
    }

    #[test]
    fn batching_preserves_request_boundaries() {
        let c = scalar_coordinator(vec![2.0], 16);
        let mut rng = Rng::seed(9);
        // different-sized requests interleaved
        let xs: Vec<Tensor> = (1..=4).map(|n| Tensor::randn(&[n, 2], 1.0, &mut rng)).collect();
        let rxs: Vec<_> = xs.iter().map(|x| c.submit(x.clone()).unwrap()).collect();
        for (x, rx) in xs.iter().zip(rxs) {
            let resp = rx.recv().unwrap();
            assert_eq!(resp.logits.dims(), x.dims());
            for (a, b) in x.data().iter().zip(resp.logits.data()) {
                assert!((a * 2.0 - b).abs() < 1e-5);
            }
        }
        c.shutdown();
    }
}
