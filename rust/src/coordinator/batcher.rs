//! Dynamic batcher with bounded-queue backpressure.
//!
//! Requests accumulate until `max_batch` samples are pending or
//! `max_wait_us` elapses since the oldest arrival — the standard
//! serving trade-off (throughput vs tail latency) the perf bench sweeps.
//!
//! Batches are *tier-grouped*: each formed batch contains only requests
//! of the head request's [`Tier`], so the scheduler can truncate the
//! basis reduction per batch without dragging lower tiers through an
//! Exact-sized broadcast. The head is always taken first (FIFO on the
//! oldest request), so no tier can starve another. The batcher also
//! exports its queue depth — the QoS pressure signal the
//! [`TermController`](crate::qos::TermController) watches.

use super::{Request, Response};
use crate::qos::Tier;
use crate::tensor::Tensor;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc;
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Batcher tuning knobs.
#[derive(Clone, Copy, Debug)]
pub struct BatcherConfig {
    /// max total samples per formed batch
    pub max_batch: usize,
    /// max time the oldest request waits before the batch is flushed
    pub max_wait_us: u64,
    /// bounded queue capacity (requests beyond this are shed)
    pub queue_cap: usize,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        BatcherConfig { max_batch: 32, max_wait_us: 2_000, queue_cap: 256 }
    }
}

/// Submission failure modes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SubmitError {
    /// queue full — caller should back off (shed-on-full backpressure)
    Busy,
    /// batcher stopped
    Closed,
}

/// One request's slot within a formed batch.
pub struct BatchPart {
    pub id: u64,
    /// number of sample rows this request contributes
    pub rows: usize,
    pub reply: mpsc::Sender<Response>,
    pub enqueued_at: Instant,
    pub tier: Tier,
}

/// A formed batch handed to the processing callback. All parts share
/// one tier (tier-grouped forming).
pub struct FormedBatch {
    /// concatenated samples (Σnᵢ, din)
    pub x: Tensor,
    pub parts: Vec<BatchPart>,
    /// requests still waiting (channel + pending) at formation time
    pub queue_depth: usize,
    /// the batcher's configured queue capacity
    pub queue_cap: usize,
}

impl FormedBatch {
    /// The batch's tier (parts are tier-homogeneous by construction).
    pub fn tier(&self) -> Tier {
        self.parts.first().map(|p| p.tier).unwrap_or_default()
    }
}

pub struct Batcher {
    tx: mpsc::SyncSender<(Request, Instant)>,
    handle: Option<JoinHandle<()>>,
    next_id: AtomicU64,
    depth: Arc<AtomicUsize>,
}

impl Batcher {
    /// Start the batching loop; `process` receives each formed batch and
    /// must reply to every part.
    pub fn start(
        cfg: BatcherConfig,
        process: impl Fn(FormedBatch) + Send + 'static,
    ) -> Batcher {
        let (tx, rx) = mpsc::sync_channel::<(Request, Instant)>(cfg.queue_cap);
        let depth = Arc::new(AtomicUsize::new(0));
        let depth2 = depth.clone();
        let handle = std::thread::Builder::new()
            .name("batcher".into())
            .spawn(move || {
                let mut pending: Vec<(Request, Instant)> = Vec::new();
                loop {
                    // wait for the first request (or shutdown)
                    if pending.is_empty() {
                        match rx.recv() {
                            Ok(r) => pending.push(r),
                            Err(_) => break,
                        }
                    }
                    // accumulate until size or deadline; the size trigger
                    // counts only the head tier's rows — that is the batch
                    // we will actually form
                    let deadline = pending[0].1 + Duration::from_micros(cfg.max_wait_us);
                    loop {
                        let head_tier = pending[0].0.tier;
                        let rows: usize = pending
                            .iter()
                            .filter(|(r, _)| r.tier == head_tier)
                            .map(|(r, _)| r.x.dims()[0])
                            .sum();
                        if rows >= cfg.max_batch {
                            break;
                        }
                        let now = Instant::now();
                        if now >= deadline {
                            break;
                        }
                        match rx.recv_timeout(deadline - now) {
                            Ok(r) => pending.push(r),
                            Err(mpsc::RecvTimeoutError::Timeout) => break,
                            Err(mpsc::RecvTimeoutError::Disconnected) => break,
                        }
                    }
                    // form the batch: the head request, then pending
                    // requests of the head's tier up to max_batch samples;
                    // other tiers stay queued for the next iteration
                    let head_tier = pending[0].0.tier;
                    let mut take = Vec::new();
                    let mut rows = 0usize;
                    let mut i = 0;
                    while i < pending.len() {
                        if pending[i].0.tier != head_tier {
                            i += 1;
                            continue;
                        }
                        let n = pending[i].0.x.dims()[0];
                        if !take.is_empty() && rows + n > cfg.max_batch {
                            break;
                        }
                        rows += n;
                        take.push(pending.remove(i));
                    }
                    depth2.fetch_sub(take.len(), Ordering::Relaxed);
                    let din = take[0].0.x.dims()[1];
                    let mut data = Vec::with_capacity(rows * din);
                    let mut parts = Vec::with_capacity(take.len());
                    for (req, at) in take {
                        assert_eq!(req.x.dims()[1], din, "mixed feature dims in batch");
                        data.extend_from_slice(req.x.data());
                        parts.push(BatchPart {
                            id: req.id,
                            rows: req.x.dims()[0],
                            reply: req.reply,
                            enqueued_at: at,
                            tier: req.tier,
                        });
                    }
                    process(FormedBatch {
                        x: Tensor::from_vec(&[rows, din], data),
                        parts,
                        queue_depth: depth2.load(Ordering::Relaxed),
                        queue_cap: cfg.queue_cap,
                    });
                }
            })
            .expect("spawn batcher");
        Batcher { tx, handle: Some(handle), next_id: AtomicU64::new(0), depth }
    }

    /// Non-blocking submit; sheds with [`SubmitError::Busy`] when full.
    pub fn submit(
        &self,
        x: Tensor,
        tier: Tier,
    ) -> Result<mpsc::Receiver<Response>, SubmitError> {
        assert_eq!(x.shape().rank(), 2, "requests are (n, din)");
        let (reply, rx) = mpsc::channel();
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        // count before sending so the batcher's decrement can never race
        // the increment below zero
        self.depth.fetch_add(1, Ordering::Relaxed);
        match self.tx.try_send((Request { id, x, tier, reply }, Instant::now())) {
            Ok(()) => Ok(rx),
            Err(mpsc::TrySendError::Full(_)) => {
                self.depth.fetch_sub(1, Ordering::Relaxed);
                Err(SubmitError::Busy)
            }
            Err(mpsc::TrySendError::Disconnected(_)) => {
                self.depth.fetch_sub(1, Ordering::Relaxed);
                Err(SubmitError::Closed)
            }
        }
    }

    /// Requests accepted but not yet formed into a batch.
    pub fn queue_depth(&self) -> usize {
        self.depth.load(Ordering::Relaxed)
    }

    pub fn shutdown(mut self) {
        drop(self.tx.clone()); // original tx dropped below
        // dropping self.tx closes the channel; the loop drains and exits
        let handle = self.handle.take();
        drop(self);
        if let Some(h) = handle {
            let _ = h.join();
        }
    }
}

impl Drop for Batcher {
    fn drop(&mut self) {
        // channel sender dropped implicitly; worker exits after drain
        if let Some(h) = self.handle.take() {
            // do not join on panic paths to avoid deadlocks in tests
            if !std::thread::panicking() {
                let _ = h;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;
    use std::sync::Arc;

    fn echo_batcher(cfg: BatcherConfig, batches_seen: Arc<AtomicUsize>) -> Batcher {
        Batcher::start(cfg, move |batch| {
            batches_seen.fetch_add(1, Ordering::SeqCst);
            let mut row = 0usize;
            for p in batch.parts {
                let din = batch.x.dims()[1];
                let data = batch.x.data()[row * din..(row + p.rows) * din].to_vec();
                row += p.rows;
                let _ = p.reply.send(Response {
                    id: p.id,
                    logits: Tensor::from_vec(&[p.rows, din], data),
                    latency_s: p.enqueued_at.elapsed().as_secs_f64(),
                    tier: p.tier,
                    terms: 0,
                    error: None,
                });
            }
        })
    }

    #[test]
    fn coalesces_small_requests_into_one_batch() {
        let seen = Arc::new(AtomicUsize::new(0));
        let b = echo_batcher(
            BatcherConfig { max_batch: 8, max_wait_us: 20_000, queue_cap: 32 },
            seen.clone(),
        );
        let rxs: Vec<_> = (0..4)
            .map(|_| {
                b.submit(Tensor::from_vec(&[1, 2], vec![1.0, 2.0]), Tier::Exact).unwrap()
            })
            .collect();
        for rx in rxs {
            let r = rx.recv().unwrap();
            assert_eq!(r.logits.dims(), &[1, 2]);
        }
        // four 1-row requests within the wait window → 1 or 2 batches
        assert!(seen.load(Ordering::SeqCst) <= 2, "batches {}", seen.load(Ordering::SeqCst));
        b.shutdown();
    }

    #[test]
    fn flushes_on_size_immediately() {
        let seen = Arc::new(AtomicUsize::new(0));
        let b = echo_batcher(
            BatcherConfig { max_batch: 2, max_wait_us: 1_000_000, queue_cap: 32 },
            seen.clone(),
        );
        let t0 = Instant::now();
        let rx1 = b.submit(Tensor::from_vec(&[1, 1], vec![1.0]), Tier::Exact).unwrap();
        let rx2 = b.submit(Tensor::from_vec(&[1, 1], vec![2.0]), Tier::Exact).unwrap();
        rx1.recv().unwrap();
        rx2.recv().unwrap();
        // must not wait the full 1 s window
        assert!(t0.elapsed() < Duration::from_millis(500));
        b.shutdown();
    }

    #[test]
    fn sheds_when_queue_full() {
        // processing blocked by a slow callback; fill the queue
        let b = Batcher::start(
            BatcherConfig { max_batch: 1, max_wait_us: 10, queue_cap: 2 },
            |batch| {
                std::thread::sleep(Duration::from_millis(200));
                for p in batch.parts {
                    let _ = p.reply.send(Response {
                        id: p.id,
                        logits: Tensor::zeros(&[p.rows, 1]),
                        latency_s: p.enqueued_at.elapsed().as_secs_f64(),
                        tier: p.tier,
                        terms: 0,
                        error: None,
                    });
                }
            },
        );
        let mut shed = 0;
        let mut keep = Vec::new();
        for _ in 0..16 {
            match b.submit(Tensor::zeros(&[1, 1]), Tier::Exact) {
                Ok(rx) => keep.push(rx),
                Err(SubmitError::Busy) => shed += 1,
                Err(e) => panic!("{e:?}"),
            }
        }
        assert!(shed > 0, "expected shedding under overload");
        // accepted requests still complete
        for rx in keep {
            assert!(rx.recv_timeout(Duration::from_secs(10)).is_ok());
        }
        b.shutdown();
    }

    #[test]
    fn oversize_request_still_processed_alone() {
        let seen = Arc::new(AtomicUsize::new(0));
        let b = echo_batcher(
            BatcherConfig { max_batch: 4, max_wait_us: 100, queue_cap: 8 },
            seen.clone(),
        );
        let rx = b.submit(Tensor::zeros(&[10, 3]), Tier::Exact).unwrap();
        let r = rx.recv().unwrap();
        assert_eq!(r.logits.dims(), &[10, 3]);
        b.shutdown();
    }

    #[test]
    fn batches_are_tier_homogeneous() {
        // interleave two tiers within one wait window; every formed batch
        // must contain a single tier and all requests must complete
        let tiers_seen = Arc::new(std::sync::Mutex::new(Vec::<Vec<Tier>>::new()));
        let ts = tiers_seen.clone();
        let b = Batcher::start(
            BatcherConfig { max_batch: 16, max_wait_us: 20_000, queue_cap: 64 },
            move |batch| {
                ts.lock().unwrap().push(batch.parts.iter().map(|p| p.tier).collect());
                for p in batch.parts {
                    let _ = p.reply.send(Response {
                        id: p.id,
                        logits: Tensor::zeros(&[p.rows, 1]),
                        latency_s: 0.0,
                        tier: p.tier,
                        terms: 0,
                        error: None,
                    });
                }
            },
        );
        let mut rxs = Vec::new();
        for i in 0..8 {
            let tier = if i % 2 == 0 { Tier::Exact } else { Tier::BestEffort };
            rxs.push(b.submit(Tensor::zeros(&[1, 1]), tier).unwrap());
        }
        for rx in rxs {
            rx.recv_timeout(Duration::from_secs(10)).unwrap();
        }
        for tiers in tiers_seen.lock().unwrap().iter() {
            assert!(tiers.windows(2).all(|w| w[0] == w[1]), "mixed batch: {tiers:?}");
        }
        b.shutdown();
    }

    #[test]
    fn queue_depth_tracks_outstanding_requests() {
        let b = Batcher::start(
            BatcherConfig { max_batch: 1, max_wait_us: 10, queue_cap: 8 },
            |batch| {
                std::thread::sleep(Duration::from_millis(100));
                for p in batch.parts {
                    let _ = p.reply.send(Response {
                        id: p.id,
                        logits: Tensor::zeros(&[p.rows, 1]),
                        latency_s: 0.0,
                        tier: p.tier,
                        terms: 0,
                        error: None,
                    });
                }
            },
        );
        let mut rxs = Vec::new();
        for _ in 0..4 {
            rxs.push(b.submit(Tensor::zeros(&[1, 1]), Tier::Exact).unwrap());
        }
        assert!(b.queue_depth() >= 2, "depth {}", b.queue_depth());
        for rx in rxs {
            rx.recv_timeout(Duration::from_secs(10)).unwrap();
        }
        // all formed: depth returns to zero
        assert_eq!(b.queue_depth(), 0);
        b.shutdown();
    }
}
