//! Dynamic batcher with bounded-queue backpressure.
//!
//! Requests accumulate until `max_batch` samples are pending or
//! `max_wait_us` elapses since the oldest arrival — the standard
//! serving trade-off (throughput vs tail latency) the perf bench sweeps.

use super::{Request, Response};
use crate::tensor::Tensor;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Batcher tuning knobs.
#[derive(Clone, Copy, Debug)]
pub struct BatcherConfig {
    /// max total samples per formed batch
    pub max_batch: usize,
    /// max time the oldest request waits before the batch is flushed
    pub max_wait_us: u64,
    /// bounded queue capacity (requests beyond this are shed)
    pub queue_cap: usize,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        BatcherConfig { max_batch: 32, max_wait_us: 2_000, queue_cap: 256 }
    }
}

/// Submission failure modes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SubmitError {
    /// queue full — caller should back off (shed-on-full backpressure)
    Busy,
    /// batcher stopped
    Closed,
}

/// A formed batch handed to the processing callback.
pub struct FormedBatch {
    /// concatenated samples (Σnᵢ, din)
    pub x: Tensor,
    /// per-request (id, rows, reply, enqueue_time)
    pub parts: Vec<(u64, usize, mpsc::Sender<Response>, Instant)>,
}

pub struct Batcher {
    tx: mpsc::SyncSender<(Request, Instant)>,
    handle: Option<JoinHandle<()>>,
    next_id: AtomicU64,
}

impl Batcher {
    /// Start the batching loop; `process` receives each formed batch and
    /// must reply to every part.
    pub fn start(
        cfg: BatcherConfig,
        process: impl Fn(FormedBatch) + Send + 'static,
    ) -> Batcher {
        let (tx, rx) = mpsc::sync_channel::<(Request, Instant)>(cfg.queue_cap);
        let handle = std::thread::Builder::new()
            .name("batcher".into())
            .spawn(move || {
                let mut pending: Vec<(Request, Instant)> = Vec::new();
                loop {
                    // wait for the first request (or shutdown)
                    if pending.is_empty() {
                        match rx.recv() {
                            Ok(r) => pending.push(r),
                            Err(_) => break,
                        }
                    }
                    // accumulate until size or deadline
                    let deadline = pending[0].1 + Duration::from_micros(cfg.max_wait_us);
                    loop {
                        let rows: usize = pending.iter().map(|(r, _)| r.x.dims()[0]).sum();
                        if rows >= cfg.max_batch {
                            break;
                        }
                        let now = Instant::now();
                        if now >= deadline {
                            break;
                        }
                        match rx.recv_timeout(deadline - now) {
                            Ok(r) => pending.push(r),
                            Err(mpsc::RecvTimeoutError::Timeout) => break,
                            Err(mpsc::RecvTimeoutError::Disconnected) => break,
                        }
                    }
                    // form the batch (split off at most max_batch samples)
                    let mut take = Vec::new();
                    let mut rows = 0usize;
                    while let Some((req, _)) = pending.first() {
                        let n = req.x.dims()[0];
                        if !take.is_empty() && rows + n > cfg.max_batch {
                            break;
                        }
                        rows += n;
                        take.push(pending.remove(0));
                    }
                    let din = take[0].0.x.dims()[1];
                    let mut data = Vec::with_capacity(rows * din);
                    let mut parts = Vec::with_capacity(take.len());
                    for (req, at) in take {
                        assert_eq!(req.x.dims()[1], din, "mixed feature dims in batch");
                        data.extend_from_slice(req.x.data());
                        parts.push((req.id, req.x.dims()[0], req.reply, at));
                    }
                    process(FormedBatch { x: Tensor::from_vec(&[rows, din], data), parts });
                }
            })
            .expect("spawn batcher");
        Batcher { tx, handle: Some(handle), next_id: AtomicU64::new(0) }
    }

    /// Non-blocking submit; sheds with [`SubmitError::Busy`] when full.
    pub fn submit(&self, x: Tensor) -> Result<mpsc::Receiver<Response>, SubmitError> {
        assert_eq!(x.shape().rank(), 2, "requests are (n, din)");
        let (reply, rx) = mpsc::channel();
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        match self.tx.try_send((Request { id, x, reply }, Instant::now())) {
            Ok(()) => Ok(rx),
            Err(mpsc::TrySendError::Full(_)) => Err(SubmitError::Busy),
            Err(mpsc::TrySendError::Disconnected(_)) => Err(SubmitError::Closed),
        }
    }

    pub fn shutdown(mut self) {
        drop(self.tx.clone()); // original tx dropped below
        // dropping self.tx closes the channel; the loop drains and exits
        let handle = self.handle.take();
        drop(self);
        if let Some(h) = handle {
            let _ = h.join();
        }
    }
}

impl Drop for Batcher {
    fn drop(&mut self) {
        // channel sender dropped implicitly; worker exits after drain
        if let Some(h) = self.handle.take() {
            // do not join on panic paths to avoid deadlocks in tests
            if !std::thread::panicking() {
                let _ = h;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;
    use std::sync::Arc;

    fn echo_batcher(cfg: BatcherConfig, batches_seen: Arc<AtomicUsize>) -> Batcher {
        Batcher::start(cfg, move |batch| {
            batches_seen.fetch_add(1, Ordering::SeqCst);
            let mut row = 0usize;
            for (id, rows, reply, at) in batch.parts {
                let din = batch.x.dims()[1];
                let data = batch.x.data()[row * din..(row + rows) * din].to_vec();
                row += rows;
                let _ = reply.send(Response {
                    id,
                    logits: Tensor::from_vec(&[rows, din], data),
                    latency_s: at.elapsed().as_secs_f64(),
                });
            }
        })
    }

    #[test]
    fn coalesces_small_requests_into_one_batch() {
        let seen = Arc::new(AtomicUsize::new(0));
        let b = echo_batcher(
            BatcherConfig { max_batch: 8, max_wait_us: 20_000, queue_cap: 32 },
            seen.clone(),
        );
        let rxs: Vec<_> =
            (0..4).map(|_| b.submit(Tensor::from_vec(&[1, 2], vec![1.0, 2.0])).unwrap()).collect();
        for rx in rxs {
            let r = rx.recv().unwrap();
            assert_eq!(r.logits.dims(), &[1, 2]);
        }
        // four 1-row requests within the wait window → 1 or 2 batches
        assert!(seen.load(Ordering::SeqCst) <= 2, "batches {}", seen.load(Ordering::SeqCst));
        b.shutdown();
    }

    #[test]
    fn flushes_on_size_immediately() {
        let seen = Arc::new(AtomicUsize::new(0));
        let b = echo_batcher(
            BatcherConfig { max_batch: 2, max_wait_us: 1_000_000, queue_cap: 32 },
            seen.clone(),
        );
        let t0 = Instant::now();
        let rx1 = b.submit(Tensor::from_vec(&[1, 1], vec![1.0])).unwrap();
        let rx2 = b.submit(Tensor::from_vec(&[1, 1], vec![2.0])).unwrap();
        rx1.recv().unwrap();
        rx2.recv().unwrap();
        // must not wait the full 1 s window
        assert!(t0.elapsed() < Duration::from_millis(500));
        b.shutdown();
    }

    #[test]
    fn sheds_when_queue_full() {
        // processing blocked by a slow callback; fill the queue
        let b = Batcher::start(
            BatcherConfig { max_batch: 1, max_wait_us: 10, queue_cap: 2 },
            |batch| {
                std::thread::sleep(Duration::from_millis(200));
                for (id, rows, reply, at) in batch.parts {
                    let _ = reply.send(Response {
                        id,
                        logits: Tensor::zeros(&[rows, 1]),
                        latency_s: at.elapsed().as_secs_f64(),
                    });
                }
            },
        );
        let mut shed = 0;
        let mut keep = Vec::new();
        for _ in 0..16 {
            match b.submit(Tensor::zeros(&[1, 1])) {
                Ok(rx) => keep.push(rx),
                Err(SubmitError::Busy) => shed += 1,
                Err(e) => panic!("{e:?}"),
            }
        }
        assert!(shed > 0, "expected shedding under overload");
        // accepted requests still complete
        for rx in keep {
            assert!(rx.recv_timeout(Duration::from_secs(10)).is_ok());
        }
        b.shutdown();
    }

    #[test]
    fn oversize_request_still_processed_alone() {
        let seen = Arc::new(AtomicUsize::new(0));
        let b = echo_batcher(
            BatcherConfig { max_batch: 4, max_wait_us: 100, queue_cap: 8 },
            seen.clone(),
        );
        let rx = b.submit(Tensor::zeros(&[10, 3])).unwrap();
        let r = rx.recv().unwrap();
        assert_eq!(r.logits.dims(), &[10, 3]);
        b.shutdown();
    }
}
