//! Dynamic batcher: one bounded queue per [`Tier`], weighted service.
//!
//! Every tier gets its own bounded FIFO with independent admission
//! control (per-tier `queue_caps` + shed accounting), and the forming
//! loop serves the queues by **weighted deficit round-robin**: each
//! top-up round grants every non-empty tier `Tier::service_weight()`
//! rows of credit, and a tier serves while its credit covers the rows
//! it can form, so contended tiers share service rows in proportion to
//! their weights, an `Exact` head can never sit behind a `Throughput`
//! burst, and no tier starves (every non-empty queue accrues credit
//! each round).
//!
//! Within the selected tier, requests accumulate until `max_batch`
//! sample rows are pending or `max_wait_us` elapses — with the
//! accumulation window anchored at **selection time**, not at the head
//! request's arrival. A request stranded while other tiers were served
//! therefore still gets a full coalescing window once its tier comes up
//! (the PR 1 single-FIFO batcher inherited the head's possibly-expired
//! window and collapsed such batches to singletons).
//!
//! Batches stay *tier-grouped* (and feature-dim-grouped: a request
//! whose `din` differs from the head's waits for its own batch rather
//! than poisoning the concatenation): each formed batch contains one
//! tier only, so the scheduler can truncate the basis reduction per
//! batch.
//! The batcher exports per-tier queue depths — the QoS pressure signal
//! the [`TermController`](crate::qos::TermController) watches — and
//! per-tier shed counts that surface as per-tier `CODE_SHED` frames in
//! the TCP protocol.

use super::{RefineSink, ReplySink, Request, Response};
use crate::qos::{Tier, NUM_TIERS};
use crate::tensor::Tensor;
use crate::util::sync::atomic::{AtomicU64, Ordering};
use crate::util::sync::thread::JoinHandle;
use crate::util::sync::{mpsc, thread, Arc, Condvar, Mutex, MutexGuard};
use std::collections::VecDeque;
use std::time::{Duration, Instant};

/// Tier-selection policy for the forming loop.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum ServicePolicy {
    /// Weighted deficit round-robin over the per-tier queues (the
    /// production policy; see module docs).
    #[default]
    WeightedFair,
    /// PR 1's single-FIFO order: always serve the tier whose head
    /// request is oldest, with the accumulation window anchored at that
    /// head's arrival (reproducing the expired-window head-of-line
    /// pathology). Kept as a measurable baseline for `perf_qos`.
    FifoArrival,
}

/// Batcher tuning knobs.
#[derive(Clone, Copy, Debug)]
pub struct BatcherConfig {
    /// max total sample rows per formed batch
    pub max_batch: usize,
    /// accumulation window once a tier is selected for service
    pub max_wait_us: u64,
    /// bounded queue capacity per tier, indexed by [`Tier::idx`]
    /// (requests beyond a tier's cap are shed with that tier's reason)
    pub queue_caps: [usize; NUM_TIERS],
    /// deficit round-robin weights (rows of service credit per
    /// rotation), indexed by [`Tier::idx`]; zero is treated as one
    pub weights: [u32; NUM_TIERS],
    /// how the forming loop picks the next tier to serve
    pub policy: ServicePolicy,
}

impl BatcherConfig {
    /// Uniform per-tier caps with the tier ladder's default weights.
    pub fn uniform(max_batch: usize, max_wait_us: u64, cap_per_tier: usize) -> BatcherConfig {
        BatcherConfig {
            max_batch,
            max_wait_us,
            queue_caps: [cap_per_tier; NUM_TIERS],
            weights: Tier::service_weights(),
            policy: ServicePolicy::WeightedFair,
        }
    }

    /// Override one tier's queue capacity.
    pub fn with_queue_cap(mut self, tier: Tier, cap: usize) -> BatcherConfig {
        self.queue_caps[tier.idx()] = cap;
        self
    }

    /// Override one tier's service weight.
    pub fn with_weight(mut self, tier: Tier, weight: u32) -> BatcherConfig {
        self.weights[tier.idx()] = weight;
        self
    }

    /// Use a different tier-selection policy.
    pub fn with_policy(mut self, policy: ServicePolicy) -> BatcherConfig {
        self.policy = policy;
        self
    }
}

impl Default for BatcherConfig {
    fn default() -> Self {
        // 256 PER TIER: caps are per-queue now, so this keeps the
        // pre-split default headroom (one shared 256-slot queue) for
        // the common single-tier traffic shape instead of tightening
        // shed onset 4× for default Exact-only callers
        BatcherConfig::uniform(32, 2_000, 256)
    }
}

/// Submission failure modes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SubmitError {
    /// that tier's queue is full — caller should back off (per-tier
    /// shed-on-full backpressure)
    Busy(Tier),
    /// batcher stopped
    Closed,
}

/// One request's slot within a formed batch.
pub struct BatchPart {
    pub id: u64,
    /// the request's trace correlation id (spans + response echo)
    pub trace_id: u64,
    /// number of sample rows this request contributes
    pub rows: usize,
    pub reply: ReplySink,
    /// progressive-refinement sink for streamed (protocol v3) requests
    pub refine: Option<RefineSink>,
    pub enqueued_at: Instant,
    pub tier: Tier,
}

/// A formed batch handed to the processing callback. All parts share
/// one tier (tier-grouped forming).
pub struct FormedBatch {
    /// concatenated samples (Σnᵢ, din)
    pub x: Tensor,
    pub parts: Vec<BatchPart>,
    /// when the batch was cut from the queue — closes each part's
    /// queue-wait span and opens the batch-formation span
    pub formed_at: Instant,
    /// per-tier queue depths (requests still waiting) at formation time
    pub tier_depths: [usize; NUM_TIERS],
    /// the batcher's configured per-tier queue capacities
    pub tier_caps: [usize; NUM_TIERS],
}

impl FormedBatch {
    /// The batch's tier (parts are tier-homogeneous by construction).
    pub fn tier(&self) -> Tier {
        self.parts.first().map(|p| p.tier).unwrap_or_default()
    }

    /// Total requests still queued across all tiers at formation time.
    pub fn queue_depth(&self) -> usize {
        self.tier_depths.iter().sum()
    }

    /// The batch's OWN tier queue occupancy (depth / cap) at formation
    /// — the admission-pressure signal fed to the QoS controller's
    /// per-tier loop. Feeding the hottest queue across tiers here
    /// (see [`FormedBatch::max_occupancy`]) is exactly the cross-tier
    /// coupling bug the per-tier controller exists to prevent: a
    /// Throughput flood must not register as pressure on a Balanced
    /// batch's decision.
    pub fn tier_occupancy(&self) -> f64 {
        let i = self.tier().idx();
        self.tier_depths[i] as f64 / self.tier_caps[i].max(1) as f64
    }

    /// Hottest per-tier occupancy (depth / cap) across the queues —
    /// aggregate observability only; the pressure signal is
    /// [`FormedBatch::tier_occupancy`].
    pub fn max_occupancy(&self) -> f64 {
        self.tier_depths
            .iter()
            .zip(&self.tier_caps)
            .map(|(&d, &c)| d as f64 / c.max(1) as f64)
            .fold(0.0, f64::max)
    }
}

type Queue = VecDeque<(Request, Instant)>;

/// The per-tier queues shared between submitters and the forming loop.
struct TierQueues {
    q: [Queue; NUM_TIERS],
    closed: bool,
}

impl TierQueues {
    fn total(&self) -> usize {
        self.q.iter().map(|d| d.len()).sum()
    }

    fn depths(&self) -> [usize; NUM_TIERS] {
        std::array::from_fn(|i| self.q[i].len())
    }

    /// Rows the selected tier could form into its next batch: requests
    /// in FIFO order sharing the head's feature dim (forming splits on
    /// a dim mismatch, so rows past one must not trip the size trigger
    /// early and flush the head as a windowless singleton), stopping
    /// once `max_batch` is reached.
    fn formable_rows(&self, tier: Tier, max_batch: usize) -> usize {
        let mut rows = 0usize;
        let mut din: Option<usize> = None;
        for (r, _) in &self.q[tier.idx()] {
            let d = r.x.dims()[1];
            match din {
                None => din = Some(d),
                Some(head_din) if head_din != d => break,
                Some(_) => {}
            }
            rows += r.x.dims()[0];
            if rows >= max_batch {
                break;
            }
        }
        rows
    }
}

struct Shared {
    m: Mutex<TierQueues>,
    cv: Condvar,
}

fn lock(shared: &Shared) -> MutexGuard<'_, TierQueues> {
    shared.m.lock().unwrap_or_else(|e| e.into_inner())
}

/// Armed inside the forming thread: if the thread exits for ANY reason
/// — including a panic in the `process` callback — the batcher is
/// marked closed and every queued request is dropped, so waiting
/// clients observe a closed reply channel and later submits get
/// [`SubmitError::Closed`] instead of queueing into a zombie (the PR 1
/// channel design had this fail-fast property implicitly; the shared
/// queues must reproduce it explicitly).
struct CloseOnExit(Arc<Shared>);

impl Drop for CloseOnExit {
    fn drop(&mut self) {
        let mut g = lock(&self.0);
        g.closed = true;
        for q in &mut g.q {
            q.clear(); // drop reply senders → receivers unblock with an error
        }
        drop(g);
        self.0.cv.notify_all();
    }
}

pub struct Batcher {
    shared: Arc<Shared>,
    cfg: BatcherConfig,
    handle: Option<JoinHandle<()>>,
    next_id: AtomicU64,
    sheds: [AtomicU64; NUM_TIERS],
}

/// Weighted deficit round-robin tier selection.
///
/// Credit is granted in *top-up rounds*: whenever no tier's remaining
/// credit covers the batch it would form, every non-empty tier accrues
/// its weight in rows. A tier then serves batch after batch while its
/// credit lasts (the cursor parks on it), so over any contended window
/// tiers share service rows in proportion to their weights — even for
/// single-row traffic, where a per-visit gate would degenerate to plain
/// round-robin. The cost charged is the rows the tier can actually form
/// right now (capped at `max_batch`), and empty queues forfeit unused
/// credit at each top-up so an idle tier cannot hoard. The deficit is
/// *signed*: the rows actually formed are charged in full, so a batch
/// that fills up during its accumulation window leaves the tier in
/// debt it must repay over later rounds — and the debt survives the
/// queue going idle — otherwise trickle-then-burst traffic would let
/// a low-weight tier overdraw to parity.
/// Starvation-free: debt per service is bounded by the batch formed,
/// every non-empty tier gains ≥ 1 row of credit per round, and its
/// cost is bounded, so it is always served within finitely many rounds.
fn select_wdrr(
    q: &TierQueues,
    deficit: &mut [i64; NUM_TIERS],
    cursor: &mut usize,
    weights: &[u32; NUM_TIERS],
    max_batch: usize,
) -> Tier {
    let cost = |q: &TierQueues, i: usize| -> i64 {
        q.formable_rows(Tier::ALL[i], max_batch).min(max_batch).max(1) as i64
    };
    loop {
        // pass 1: serve from the cursor with existing credit
        for k in 0..NUM_TIERS {
            let i = (*cursor + k) % NUM_TIERS;
            if !q.q[i].is_empty() && deficit[i] >= cost(q, i) {
                *cursor = i; // park: keep serving while credit lasts
                return Tier::ALL[i];
            }
        }
        // nobody has credit: one top-up round (callers guarantee at
        // least one queue is non-empty, so this terminates)
        for i in 0..NUM_TIERS {
            if q.q[i].is_empty() {
                // forfeit unused credit only — debt survives idling, or
                // trickle-then-burst traffic could overdraw each cycle
                // and have the slate wiped while its queue sits empty
                deficit[i] = deficit[i].min(0);
            } else {
                deficit[i] += weights[i].max(1) as i64;
            }
        }
    }
}

/// PR 1 arrival-order selection: the tier whose head request is oldest.
fn select_fifo(q: &TierQueues) -> (Tier, Instant) {
    let mut best: Option<(Tier, Instant)> = None;
    for t in Tier::ALL {
        if let Some((_, at)) = q.q[t.idx()].front() {
            let older = match best {
                None => true,
                Some((_, b)) => *at < b,
            };
            if older {
                best = Some((t, *at));
            }
        }
    }
    best.expect("select_fifo called with all queues empty")
}

impl Batcher {
    /// Start the batching loop; `process` receives each formed batch and
    /// must reply to every part.
    pub fn start(
        cfg: BatcherConfig,
        process: impl Fn(FormedBatch) + Send + 'static,
    ) -> Batcher {
        assert!(cfg.max_batch >= 1, "max_batch must be at least 1");
        assert!(
            cfg.queue_caps.iter().all(|&c| c >= 1),
            "every tier needs queue capacity >= 1"
        );
        let shared = Arc::new(Shared {
            m: Mutex::new(TierQueues {
                q: std::array::from_fn(|_| VecDeque::new()),
                closed: false,
            }),
            cv: Condvar::new(),
        });
        let shared2 = shared.clone();
        let handle = thread::Builder::new()
            .name("batcher".into())
            .spawn(move || {
                let _close_on_exit = CloseOnExit(shared2.clone());
                let mut deficit = [0i64; NUM_TIERS];
                let mut cursor = 0usize;
                loop {
                    // wait for any request (or shutdown); on shutdown the
                    // queues are drained before the loop exits, so accepted
                    // requests always get a reply
                    let mut g = lock(&shared2);
                    while g.total() == 0 && !g.closed {
                        g = shared2.cv.wait(g).unwrap_or_else(|e| e.into_inner());
                    }
                    if g.total() == 0 && g.closed {
                        break;
                    }

                    // pick the tier to serve and anchor its window
                    let (tier, window_start) = match cfg.policy {
                        ServicePolicy::WeightedFair => (
                            select_wdrr(
                                &g,
                                &mut deficit,
                                &mut cursor,
                                &cfg.weights,
                                cfg.max_batch,
                            ),
                            Instant::now(),
                        ),
                        ServicePolicy::FifoArrival => select_fifo(&g),
                    };

                    // accumulate until size or deadline (lock released
                    // while waiting); closing flushes immediately
                    let deadline = window_start + Duration::from_micros(cfg.max_wait_us);
                    loop {
                        if g.closed || g.formable_rows(tier, cfg.max_batch) >= cfg.max_batch {
                            break;
                        }
                        let now = Instant::now();
                        if now >= deadline {
                            break;
                        }
                        g = shared2
                            .cv
                            .wait_timeout(g, deadline - now)
                            .unwrap_or_else(|e| e.into_inner())
                            .0;
                    }

                    // form the batch: head always taken, then FIFO within
                    // the tier up to max_batch rows; only requests
                    // matching the head's feature dim coalesce (a
                    // mismatched request simply waits for its own batch —
                    // a remote caller must not be able to panic this
                    // thread by mixing dims within one window)
                    let mut take: Vec<(Request, Instant)> = Vec::new();
                    let mut rows = 0usize;
                    let batch_din = g.q[tier.idx()]
                        .front()
                        .map(|(r, _)| r.x.dims()[1])
                        .expect("selected tier is non-empty");
                    while let Some(front) = g.q[tier.idx()].front() {
                        let n = front.0.x.dims()[0];
                        if !take.is_empty()
                            && (rows + n > cfg.max_batch || front.0.x.dims()[1] != batch_din)
                        {
                            break;
                        }
                        rows += n;
                        take.push(g.q[tier.idx()].pop_front().expect("front checked"));
                    }
                    let tier_depths = g.depths();
                    let formed_at = Instant::now();
                    drop(g);
                    // charge the rows actually served; going negative is
                    // the debt mechanism that keeps shares weighted when
                    // the window filled a batch beyond the selection cost
                    deficit[tier.idx()] -= rows as i64;

                    let din = batch_din;
                    let mut data = Vec::with_capacity(rows * din);
                    let mut parts = Vec::with_capacity(take.len());
                    for (req, at) in take {
                        data.extend_from_slice(req.x.data());
                        parts.push(BatchPart {
                            id: req.id,
                            trace_id: req.trace_id,
                            rows: req.x.dims()[0],
                            reply: req.reply,
                            refine: req.refine,
                            enqueued_at: at,
                            tier: req.tier,
                        });
                    }
                    process(FormedBatch {
                        x: Tensor::from_vec(&[rows, din], data),
                        parts,
                        formed_at,
                        tier_depths,
                        tier_caps: cfg.queue_caps,
                    });
                }
            })
            .expect("spawn batcher");
        Batcher {
            shared,
            cfg,
            handle: Some(handle),
            next_id: AtomicU64::new(0),
            sheds: std::array::from_fn(|_| AtomicU64::new(0)),
        }
    }

    /// Non-blocking submit; sheds with [`SubmitError::Busy`] naming the
    /// tier whose queue was full.
    pub fn submit(
        &self,
        x: Tensor,
        tier: Tier,
    ) -> Result<mpsc::Receiver<Response>, SubmitError> {
        self.submit_traced(x, tier, 0)
    }

    /// [`Batcher::submit`] carrying the request's trace id into the
    /// formed batch (and so into every span and the response echo).
    pub fn submit_traced(
        &self,
        x: Tensor,
        tier: Tier,
        trace_id: u64,
    ) -> Result<mpsc::Receiver<Response>, SubmitError> {
        let (reply, rx) = mpsc::channel();
        self.submit_with_sink(x, tier, trace_id, ReplySink::Channel(reply), None)?;
        Ok(rx)
    }

    /// Sink-carrying submission (the reactor front-end): the reply goes
    /// through `sink` instead of a fresh channel, and a streamed
    /// request's refinement hooks ride along into the formed batch.
    pub fn submit_with_sink(
        &self,
        x: Tensor,
        tier: Tier,
        trace_id: u64,
        sink: ReplySink,
        refine: Option<RefineSink>,
    ) -> Result<(), SubmitError> {
        assert_eq!(x.shape().rank(), 2, "requests are (n, din)");
        // ordering: Relaxed — id allocation only needs uniqueness (RMW
        // atomicity); the request itself travels under the queue mutex.
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let mut g = lock(&self.shared);
        if g.closed {
            return Err(SubmitError::Closed);
        }
        if g.q[tier.idx()].len() >= self.cfg.queue_caps[tier.idx()] {
            // ordering: Relaxed — a statistics counter; readers need a
            // count, not an edge.
            self.sheds[tier.idx()].fetch_add(1, Ordering::Relaxed);
            return Err(SubmitError::Busy(tier));
        }
        let req = Request { id, trace_id, x, tier, reply: sink, refine };
        g.q[tier.idx()].push_back((req, Instant::now()));
        drop(g);
        self.shared.cv.notify_all();
        Ok(())
    }

    /// Count an externally decided shed — the reactor sheds at a slow
    /// reader's own tier on write backpressure, before the request ever
    /// reaches the admission check — so the per-tier shed statistics
    /// cover every `CODE_SHED` frame on the wire.
    pub fn record_shed(&self, tier: Tier) {
        // ordering: Relaxed — a statistics counter; readers need a
        // count, not an edge.
        self.sheds[tier.idx()].fetch_add(1, Ordering::Relaxed);
    }

    /// Requests accepted but not yet formed into a batch, across tiers.
    pub fn queue_depth(&self) -> usize {
        lock(&self.shared).total()
    }

    /// Requests of one tier accepted but not yet formed into a batch.
    pub fn tier_depth(&self, tier: Tier) -> usize {
        lock(&self.shared).q[tier.idx()].len()
    }

    /// Requests shed at `tier`'s admission check since start.
    pub fn shed_count(&self, tier: Tier) -> u64 {
        // ordering: Relaxed — statistics read of a lone counter.
        self.sheds[tier.idx()].load(Ordering::Relaxed)
    }

    fn stop(&mut self) {
        {
            let mut g = lock(&self.shared);
            g.closed = true;
        }
        self.shared.cv.notify_all();
        if let Some(h) = self.handle.take() {
            // join unless we are unwinding (a panicking test must not
            // deadlock on a wedged process callback). NOTE: the join
            // waits for any in-flight `process` call to return — that
            // is the contract that makes accepted replies durable; a
            // backend that can block forever must enforce its own
            // timeout, since std gives no timed join
            if thread::panicking() {
                drop(h);
            } else {
                let _ = h.join();
            }
        }
    }

    /// Drain the queues (every accepted request gets its reply) and
    /// join the forming thread.
    pub fn shutdown(mut self) {
        self.stop();
    }
}

impl Drop for Batcher {
    fn drop(&mut self) {
        // same as shutdown: drain, reply, join — a dropped batcher must
        // not detach its thread and lose in-flight replies at exit
        self.stop();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;
    use std::sync::Arc;

    fn echo_batcher(cfg: BatcherConfig, batches_seen: Arc<AtomicUsize>) -> Batcher {
        Batcher::start(cfg, move |batch| {
            batches_seen.fetch_add(1, Ordering::SeqCst);
            let mut row = 0usize;
            for p in batch.parts {
                let din = batch.x.dims()[1];
                let data = batch.x.data()[row * din..(row + p.rows) * din].to_vec();
                row += p.rows;
                p.reply.send(Response {
                    id: p.id,
                    trace_id: p.trace_id,
                    logits: Tensor::from_vec(&[p.rows, din], data),
                    latency_s: p.enqueued_at.elapsed().as_secs_f64(),
                    tier: p.tier,
                    terms: 0,
                    grid_terms: 0,
                    error: None,
                });
            }
        })
    }

    fn zero_reply(batch: FormedBatch) {
        for p in batch.parts {
            p.reply.send(Response {
                id: p.id,
                trace_id: p.trace_id,
                logits: Tensor::zeros(&[p.rows, 1]),
                latency_s: p.enqueued_at.elapsed().as_secs_f64(),
                tier: p.tier,
                terms: 0,
                grid_terms: 0,
                error: None,
            });
        }
    }

    #[test]
    fn coalesces_small_requests_into_one_batch() {
        let seen = Arc::new(AtomicUsize::new(0));
        let b = echo_batcher(BatcherConfig::uniform(8, 20_000, 32), seen.clone());
        let rxs: Vec<_> = (0..4)
            .map(|_| {
                b.submit(Tensor::from_vec(&[1, 2], vec![1.0, 2.0]), Tier::Exact).unwrap()
            })
            .collect();
        for rx in rxs {
            let r = rx.recv().unwrap();
            assert_eq!(r.logits.dims(), &[1, 2]);
        }
        // four 1-row requests within the wait window → 1 or 2 batches
        assert!(seen.load(Ordering::SeqCst) <= 2, "batches {}", seen.load(Ordering::SeqCst));
        b.shutdown();
    }

    #[test]
    fn flushes_on_size_immediately() {
        let seen = Arc::new(AtomicUsize::new(0));
        let b = echo_batcher(BatcherConfig::uniform(2, 1_000_000, 32), seen.clone());
        let t0 = Instant::now();
        let rx1 = b.submit(Tensor::from_vec(&[1, 1], vec![1.0]), Tier::Exact).unwrap();
        let rx2 = b.submit(Tensor::from_vec(&[1, 1], vec![2.0]), Tier::Exact).unwrap();
        rx1.recv().unwrap();
        rx2.recv().unwrap();
        // must not wait the full 1 s window
        assert!(t0.elapsed() < Duration::from_millis(500));
        b.shutdown();
    }

    #[test]
    fn sheds_when_queue_full() {
        // processing blocked by a slow callback; fill the Exact queue
        let b = Batcher::start(BatcherConfig::uniform(1, 10, 2), |batch| {
            std::thread::sleep(Duration::from_millis(200));
            zero_reply(batch);
        });
        let mut shed = 0;
        let mut keep = Vec::new();
        for _ in 0..16 {
            match b.submit(Tensor::zeros(&[1, 1]), Tier::Exact) {
                Ok(rx) => keep.push(rx),
                Err(SubmitError::Busy(t)) => {
                    assert_eq!(t, Tier::Exact, "shed reason names the full queue");
                    shed += 1;
                }
                Err(e) => panic!("{e:?}"),
            }
        }
        assert!(shed > 0, "expected shedding under overload");
        assert_eq!(b.shed_count(Tier::Exact), shed as u64);
        assert_eq!(b.shed_count(Tier::BestEffort), 0);
        // accepted requests still complete
        for rx in keep {
            assert!(rx.recv_timeout(Duration::from_secs(10)).is_ok());
        }
        b.shutdown();
    }

    #[test]
    fn admission_is_per_tier() {
        // a full Throughput queue must not block an Exact submit
        let b = Batcher::start(
            BatcherConfig::uniform(1, 10, 1).with_queue_cap(Tier::Exact, 8),
            |batch| {
                std::thread::sleep(Duration::from_millis(100));
                zero_reply(batch);
            },
        );
        let mut rxs = Vec::new();
        let mut throughput_shed = false;
        for _ in 0..8 {
            match b.submit(Tensor::zeros(&[1, 1]), Tier::Throughput) {
                Ok(rx) => rxs.push(rx),
                Err(SubmitError::Busy(Tier::Throughput)) => throughput_shed = true,
                Err(e) => panic!("{e:?}"),
            }
        }
        assert!(throughput_shed, "cap-1 tier queue must overflow");
        // Exact admission is independent of the flooded tier
        rxs.push(b.submit(Tensor::zeros(&[1, 1]), Tier::Exact).unwrap());
        for rx in rxs {
            assert!(rx.recv_timeout(Duration::from_secs(10)).is_ok());
        }
        b.shutdown();
    }

    #[test]
    fn oversize_request_still_processed_alone() {
        let seen = Arc::new(AtomicUsize::new(0));
        let b = echo_batcher(BatcherConfig::uniform(4, 100, 8), seen.clone());
        let rx = b.submit(Tensor::zeros(&[10, 3]), Tier::Exact).unwrap();
        let r = rx.recv().unwrap();
        assert_eq!(r.logits.dims(), &[10, 3]);
        b.shutdown();
    }

    #[test]
    fn batches_are_tier_homogeneous() {
        // interleave two tiers within one wait window; every formed batch
        // must contain a single tier and all requests must complete
        let tiers_seen = Arc::new(std::sync::Mutex::new(Vec::<Vec<Tier>>::new()));
        let ts = tiers_seen.clone();
        let b = Batcher::start(BatcherConfig::uniform(16, 20_000, 64), move |batch| {
            ts.lock().unwrap().push(batch.parts.iter().map(|p| p.tier).collect());
            zero_reply(batch);
        });
        let mut rxs = Vec::new();
        for i in 0..8 {
            let tier = if i % 2 == 0 { Tier::Exact } else { Tier::BestEffort };
            rxs.push(b.submit(Tensor::zeros(&[1, 1]), tier).unwrap());
        }
        for rx in rxs {
            rx.recv_timeout(Duration::from_secs(10)).unwrap();
        }
        for tiers in tiers_seen.lock().unwrap().iter() {
            assert!(tiers.windows(2).all(|w| w[0] == w[1]), "mixed batch: {tiers:?}");
        }
        b.shutdown();
    }

    #[test]
    fn queue_depth_tracks_outstanding_requests() {
        let b = Batcher::start(BatcherConfig::uniform(1, 10, 8), |batch| {
            std::thread::sleep(Duration::from_millis(100));
            zero_reply(batch);
        });
        let mut rxs = Vec::new();
        for _ in 0..4 {
            rxs.push(b.submit(Tensor::zeros(&[1, 1]), Tier::Balanced).unwrap());
        }
        assert!(b.queue_depth() >= 2, "depth {}", b.queue_depth());
        assert!(b.tier_depth(Tier::Balanced) >= 2, "{}", b.tier_depth(Tier::Balanced));
        assert_eq!(b.tier_depth(Tier::Exact), 0);
        for rx in rxs {
            rx.recv_timeout(Duration::from_secs(10)).unwrap();
        }
        // all formed: depth returns to zero
        assert_eq!(b.queue_depth(), 0);
        b.shutdown();
    }

    #[test]
    fn exact_head_overtakes_a_flooded_tier() {
        // a BestEffort flood is queued; an Exact request arriving later
        // must be served within one WDRR rotation of the in-flight batch,
        // not after the whole flood
        let order = Arc::new(std::sync::Mutex::new(Vec::<Tier>::new()));
        let o2 = order.clone();
        let b = Batcher::start(BatcherConfig::uniform(1, 10, 64), move |batch| {
            o2.lock().unwrap().push(batch.tier());
            std::thread::sleep(Duration::from_millis(30));
            zero_reply(batch);
        });
        let mut rxs = Vec::new();
        for _ in 0..12 {
            rxs.push(b.submit(Tensor::zeros(&[1, 1]), Tier::BestEffort).unwrap());
        }
        // let the flood's first batch enter service, then submit Exact
        std::thread::sleep(Duration::from_millis(45));
        let exact_rx = b.submit(Tensor::zeros(&[1, 1]), Tier::Exact).unwrap();
        exact_rx.recv_timeout(Duration::from_secs(10)).unwrap();
        for rx in rxs {
            rx.recv_timeout(Duration::from_secs(10)).unwrap();
        }
        let order = order.lock().unwrap().clone();
        let exact_pos = order.iter().position(|&t| t == Tier::Exact).expect("exact served");
        assert!(
            exact_pos <= 3,
            "exact waited behind the flood: served at position {exact_pos} of {order:?}"
        );
        b.shutdown();
    }

    #[test]
    fn stranded_tier_gets_a_full_accumulation_window() {
        // regression for the PR 1 expired-deadline bug: a request stranded
        // while another tier was in service must still get a full
        // coalescing window once its tier is selected, so a companion
        // arriving during that window joins the same batch
        let batches = Arc::new(std::sync::Mutex::new(Vec::<Vec<Tier>>::new()));
        let bt = batches.clone();
        let gate = Arc::new(std::sync::Mutex::new(()));
        let gate2 = gate.clone();
        let first = Arc::new(AtomicUsize::new(0));
        let f2 = first.clone();
        let b = Batcher::start(BatcherConfig::uniform(2, 300_000, 16), move |batch| {
            // the first (Exact) batch blocks in service until the gate
            // opens, stranding the Balanced request behind it
            if f2.fetch_add(1, Ordering::SeqCst) == 0 {
                let _g = gate2.lock().unwrap();
            }
            bt.lock().unwrap().push(batch.parts.iter().map(|p| p.tier).collect());
            zero_reply(batch);
        });
        let hold = gate.lock().unwrap();
        let rx_a = b.submit(Tensor::zeros(&[2, 1]), Tier::Exact).unwrap(); // size-triggers
        std::thread::sleep(Duration::from_millis(30)); // Exact batch now in service
        let rx_b1 = b.submit(Tensor::zeros(&[1, 1]), Tier::Balanced).unwrap();
        // strand B1 well past its own arrival window's worth of waiting
        std::thread::sleep(Duration::from_millis(100));
        drop(hold); // Exact batch completes; Balanced is selected now
        std::thread::sleep(Duration::from_millis(50));
        // B2 arrives during B1's (fresh) window — must join B1's batch
        let rx_b2 = b.submit(Tensor::zeros(&[1, 1]), Tier::Balanced).unwrap();
        for rx in [rx_a, rx_b1, rx_b2] {
            rx.recv_timeout(Duration::from_secs(10)).unwrap();
        }
        let batches = batches.lock().unwrap().clone();
        let balanced: Vec<&Vec<Tier>> =
            batches.iter().filter(|b| b.contains(&Tier::Balanced)).collect();
        assert_eq!(
            balanced.len(),
            1,
            "stranded request was flushed alone instead of coalescing: {batches:?}"
        );
        assert_eq!(balanced[0].len(), 2);
        b.shutdown();
    }

    #[test]
    fn fifo_policy_reproduces_the_expired_window_pathology() {
        // the FifoArrival baseline anchors the window at head arrival, so
        // the same stranding scenario collapses to singleton batches —
        // this is the measurable contrast perf_qos reports
        let batches = Arc::new(std::sync::Mutex::new(Vec::<Vec<Tier>>::new()));
        let bt = batches.clone();
        let gate = Arc::new(std::sync::Mutex::new(()));
        let gate2 = gate.clone();
        let first = Arc::new(AtomicUsize::new(0));
        let f2 = first.clone();
        let b = Batcher::start(
            BatcherConfig::uniform(2, 50_000, 16).with_policy(ServicePolicy::FifoArrival),
            move |batch| {
                if f2.fetch_add(1, Ordering::SeqCst) == 0 {
                    let _g = gate2.lock().unwrap();
                }
                bt.lock().unwrap().push(batch.parts.iter().map(|p| p.tier).collect());
                zero_reply(batch);
            },
        );
        let hold = gate.lock().unwrap();
        let rx_a = b.submit(Tensor::zeros(&[2, 1]), Tier::Exact).unwrap();
        std::thread::sleep(Duration::from_millis(30));
        let rx_b1 = b.submit(Tensor::zeros(&[1, 1]), Tier::Balanced).unwrap();
        // strand B1 past its 50 ms arrival-anchored window
        std::thread::sleep(Duration::from_millis(100));
        drop(hold);
        std::thread::sleep(Duration::from_millis(30));
        let rx_b2 = b.submit(Tensor::zeros(&[1, 1]), Tier::Balanced).unwrap();
        for rx in [rx_a, rx_b1, rx_b2] {
            rx.recv_timeout(Duration::from_secs(10)).unwrap();
        }
        let batches = batches.lock().unwrap().clone();
        let balanced_batches =
            batches.iter().filter(|b| b.contains(&Tier::Balanced)).count();
        assert_eq!(
            balanced_batches, 2,
            "fifo baseline should flush the stranded request alone: {batches:?}"
        );
        b.shutdown();
    }

    #[test]
    fn drop_drains_and_joins_the_worker() {
        // dropping (not shutting down) the batcher must still deliver
        // every accepted reply before the thread is joined
        let seen = Arc::new(AtomicUsize::new(0));
        let b = echo_batcher(BatcherConfig::uniform(4, 1_000, 16), seen.clone());
        let rxs: Vec<_> = (0..6)
            .map(|_| b.submit(Tensor::zeros(&[1, 1]), Tier::Throughput).unwrap())
            .collect();
        drop(b); // Drop drains + joins — replies must already be sent
        for rx in rxs {
            assert!(rx.try_recv().is_ok(), "in-flight reply lost on drop");
        }
    }

    #[test]
    fn mixed_feature_dims_split_batches_instead_of_panicking() {
        // a remote caller mixing dims within one window must get two
        // clean batches — never a forming-thread panic (which would
        // zombie the batcher for every later client)
        let seen = Arc::new(AtomicUsize::new(0));
        let b = echo_batcher(BatcherConfig::uniform(8, 5_000, 16), seen.clone());
        let rx1 = b.submit(Tensor::zeros(&[1, 4]), Tier::Exact).unwrap();
        let rx2 = b.submit(Tensor::zeros(&[1, 5]), Tier::Exact).unwrap();
        assert_eq!(
            rx1.recv_timeout(Duration::from_secs(10)).unwrap().logits.dims(),
            &[1, 4]
        );
        assert_eq!(
            rx2.recv_timeout(Duration::from_secs(10)).unwrap().logits.dims(),
            &[1, 5]
        );
        // the forming thread survived: new work still completes
        let rx3 = b.submit(Tensor::zeros(&[2, 3]), Tier::Balanced).unwrap();
        assert!(rx3.recv_timeout(Duration::from_secs(10)).is_ok());
        b.shutdown();
    }

    #[test]
    fn forming_thread_death_fails_fast_not_zombie() {
        // if the process callback panics, queued clients must see a
        // dropped reply channel and later submits must get Closed —
        // not an ever-growing queue nobody will ever serve
        let b = Batcher::start(BatcherConfig::uniform(1, 10, 8), |batch| {
            if batch.tier() == Tier::BestEffort {
                panic!("injected process panic");
            }
            zero_reply(batch);
        });
        let rx = b.submit(Tensor::zeros(&[1, 1]), Tier::BestEffort).unwrap();
        assert!(
            rx.recv_timeout(Duration::from_secs(10)).is_err(),
            "client of the panicked batch must observe a closed channel"
        );
        // the close-on-exit guard marks the batcher closed for new work
        let mut closed = false;
        for _ in 0..100 {
            match b.submit(Tensor::zeros(&[1, 1]), Tier::Exact) {
                Err(SubmitError::Closed) => {
                    closed = true;
                    break;
                }
                _ => std::thread::sleep(Duration::from_millis(10)),
            }
        }
        assert!(closed, "submits after a forming-thread panic must fail fast");
        b.shutdown();
    }

    #[test]
    fn weighted_service_shares_rows_by_tier_weight() {
        // sustained two-tier contention with single-row requests: WDRR
        // must split service ~8:1 (Exact:BestEffort weights), not 1:1 —
        // the regression a per-visit credit gate would reintroduce
        let order = Arc::new(std::sync::Mutex::new(Vec::<Tier>::new()));
        let o2 = order.clone();
        let b = Batcher::start(BatcherConfig::uniform(1, 10, 64), move |batch| {
            o2.lock().unwrap().push(batch.tier());
            std::thread::sleep(Duration::from_millis(5));
            zero_reply(batch);
        });
        let mut rxs = Vec::new();
        for _ in 0..24 {
            rxs.push(b.submit(Tensor::zeros(&[1, 1]), Tier::Exact).unwrap());
        }
        for _ in 0..24 {
            rxs.push(b.submit(Tensor::zeros(&[1, 1]), Tier::BestEffort).unwrap());
        }
        for rx in rxs {
            rx.recv_timeout(Duration::from_secs(10)).unwrap();
        }
        let order = order.lock().unwrap().clone();
        // both queues were full for (at least) the first 18 services;
        // weight 8 vs 1 → expect ~16 Exact per 18, and BestEffort must
        // still appear (no starvation)
        let window = &order[..18];
        let exact = window.iter().filter(|&&t| t == Tier::Exact).count();
        let best_effort = window.iter().filter(|&&t| t == Tier::BestEffort).count();
        assert!(exact >= 12, "weights ignored: {exact}/18 exact in {order:?}");
        assert!(best_effort >= 1, "low-weight tier starved: {order:?}");
        b.shutdown();
    }

    #[test]
    fn formed_batch_occupancy_is_per_tier() {
        let (reply, _rx) = mpsc::channel();
        let batch = FormedBatch {
            x: Tensor::zeros(&[1, 1]),
            parts: vec![BatchPart {
                id: 0,
                trace_id: 0,
                rows: 1,
                reply: ReplySink::Channel(reply),
                refine: None,
                enqueued_at: Instant::now(),
                tier: Tier::Balanced,
            }],
            formed_at: Instant::now(),
            // Throughput's queue is saturated; Balanced's is nearly idle
            tier_depths: [12, 2, 16, 0],
            tier_caps: [16; NUM_TIERS],
        };
        // the batch's own tier is the pressure signal, not the hottest
        assert!((batch.tier_occupancy() - 2.0 / 16.0).abs() < 1e-12);
        assert!((batch.max_occupancy() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn submit_after_stop_returns_closed() {
        let mut b =
            echo_batcher(BatcherConfig::uniform(4, 100, 16), Arc::new(AtomicUsize::new(0)));
        b.stop();
        let err = b.submit(Tensor::zeros(&[1, 1]), Tier::Exact).err();
        assert_eq!(err, Some(SubmitError::Closed));
    }
}

/// Loom model for the shutdown/drop drain contract. Run with
/// `RUSTFLAGS="--cfg loom" cargo test --release --lib loom_model_`
/// (see CONCURRENCY.md).
#[cfg(all(test, loom))]
mod loom_models {
    use super::*;
    use crate::util::sync::thread as model_thread;

    /// Submitters race `shutdown()`: every submit the batcher *accepts*
    /// must have its reply delivered by the time `shutdown` returns
    /// (the forming loop drains non-empty queues before exiting, and
    /// the join makes that drain visible). `try_recv` keeps the model
    /// free of scheduler-invisible blocking; `max_wait_us = 0` makes
    /// the accumulation window elapse immediately so the real deadline
    /// loop exits without timed waits.
    #[test]
    fn loom_model_shutdown_drains_accepted_submits() {
        loom::model_iters(256, || {
            let b = Arc::new(Batcher::start(BatcherConfig::uniform(4, 0, 4), |batch| {
                for p in batch.parts {
                    p.reply.send(Response {
                        id: p.id,
                        trace_id: p.trace_id,
                        logits: Tensor::zeros(&[p.rows, 1]),
                        latency_s: 0.0,
                        tier: p.tier,
                        terms: 0,
                        grid_terms: 0,
                        error: None,
                    });
                }
            }));
            let subs: Vec<_> = (0..2u64)
                .map(|k| {
                    let b = Arc::clone(&b);
                    model_thread::spawn(move || {
                        let x = Tensor::from_vec(&[1, 1], vec![k as f32]);
                        b.submit(x, Tier::Exact).ok()
                    })
                })
                .collect();
            let rxs: Vec<_> = subs.into_iter().map(|h| h.join().unwrap()).collect();
            let b = Arc::try_unwrap(b).ok().expect("submitters released their handles");
            b.shutdown();
            for rx in rxs.into_iter().flatten() {
                rx.try_recv().expect("accepted submit lost its reply across shutdown");
            }
        });
    }
}
