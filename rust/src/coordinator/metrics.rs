//! Serving metrics: lock-free counters + latency reservoirs, aggregated
//! and per QoS tier (latency, terms served, estimated precision loss).

use crate::qos::{Tier, NUM_TIERS};
use crate::util::stats::Histogram;
use crate::util::sync::atomic::{AtomicU64, Ordering};
use crate::util::sync::Mutex;

// ordering: every atomic in this module is Relaxed by design — they are
// monotonic statistics counters read individually for reporting. No
// reader dereferences memory published under a counter, and exposition
// snapshots are allowed to be mutually out-of-date by a few events.
// Per-site comments below restate this where the lint wants them local.

/// Coordinator-wide metrics.
#[derive(Debug)]
pub struct Metrics {
    completed: AtomicU64,
    failed: AtomicU64,
    batches: AtomicU64,
    samples: AtomicU64,
    /// request latencies (seconds); reservoir capped to keep memory flat
    latencies: Mutex<Vec<f64>>,
    /// batch service times (seconds)
    batch_times: Mutex<Vec<f64>>,
    /// per-tier counters, indexed by [`Tier::idx`]
    tier_completed: [AtomicU64; NUM_TIERS],
    /// per-tier failed requests (batch-execution errors)
    tier_failed: [AtomicU64; NUM_TIERS],
    /// per-tier sum of terms reduced (mean = /completed)
    tier_terms: [AtomicU64; NUM_TIERS],
    /// per-tier sum of INT GEMM grid terms executed by budget-aware
    /// workers, recorded once per formed batch (a batch's forward is
    /// shared by its requests, so per-request attribution would scale
    /// with batch size and make tiers incomparable)
    tier_grid_terms: [AtomicU64; NUM_TIERS],
    /// per-tier count of batches with grid accounting (mean divisor)
    tier_grid_batches: [AtomicU64; NUM_TIERS],
    /// per-tier sum of *planned* grid ceilings (the BudgetPlan's total
    /// at serve time) and the batch count that carried one. NOTE: the
    /// ceiling is an allocation-level pair count (one model forward's
    /// grid), while executed `tier_grid_terms` sums over every prefix
    /// worker and every conv image row — track the ceiling as "what the
    /// controller allocated", not as a ratio against executed spend
    tier_planned_grid: [AtomicU64; NUM_TIERS],
    tier_planned_batches: [AtomicU64; NUM_TIERS],
    /// per-tier latency reservoirs
    tier_latencies: [Mutex<Vec<f64>>; NUM_TIERS],
    /// per-tier fixed-bucket latency histograms — unlike the reservoir
    /// (bounded, first-come) these never saturate and export directly
    /// as Prometheus `le` buckets
    tier_hist: [Mutex<Histogram>; NUM_TIERS],
    /// per-tier worst estimated precision loss (max-residual estimate
    /// from the controller's calibration; NAN-free, 0 when unknown)
    tier_loss: Mutex<[f64; NUM_TIERS]>,
}

const RESERVOIR_CAP: usize = 100_000;

impl Default for Metrics {
    fn default() -> Metrics {
        Metrics::new()
    }
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics {
            completed: AtomicU64::new(0),
            failed: AtomicU64::new(0),
            batches: AtomicU64::new(0),
            samples: AtomicU64::new(0),
            latencies: Mutex::default(),
            batch_times: Mutex::default(),
            tier_completed: Default::default(),
            tier_failed: Default::default(),
            tier_terms: Default::default(),
            tier_grid_terms: Default::default(),
            tier_grid_batches: Default::default(),
            tier_planned_grid: Default::default(),
            tier_planned_batches: Default::default(),
            tier_latencies: Default::default(),
            tier_hist: std::array::from_fn(|_| Mutex::new(Histogram::latency_seconds())),
            tier_loss: Mutex::new([0.0; NUM_TIERS]),
        }
    }

    pub fn record_completed(&self, latency_s: f64) {
        self.record_completed_tier(Tier::Exact, latency_s, 0, None);
    }

    /// Record one completed request with its serving detail.
    pub fn record_completed_tier(
        &self,
        tier: Tier,
        latency_s: f64,
        terms: usize,
        est_loss: Option<f32>,
    ) {
        // ordering: Relaxed — statistics counter (module note).
        self.completed.fetch_add(1, Ordering::Relaxed);
        let mut l = self.latencies.lock().unwrap();
        if l.len() < RESERVOIR_CAP {
            l.push(latency_s);
        }
        drop(l);
        let i = tier.idx();
        // ordering: Relaxed — statistics counters (module note).
        self.tier_completed[i].fetch_add(1, Ordering::Relaxed);
        self.tier_terms[i].fetch_add(terms as u64, Ordering::Relaxed);
        let mut tl = self.tier_latencies[i].lock().unwrap();
        if tl.len() < RESERVOIR_CAP {
            tl.push(latency_s);
        }
        drop(tl);
        self.tier_hist[i].lock().unwrap().observe(latency_s);
        if let Some(loss) = est_loss {
            let mut worst = self.tier_loss.lock().unwrap();
            worst[i] = worst[i].max(loss as f64);
        }
    }

    pub fn record_failed(&self, n: usize) {
        // ordering: Relaxed — statistics counter (module note).
        self.failed.fetch_add(n as u64, Ordering::Relaxed);
    }

    /// [`Metrics::record_failed`] with tier attribution, so the
    /// exposition can break failures out per tier.
    pub fn record_failed_tier(&self, tier: Tier, n: usize) {
        self.record_failed(n);
        // ordering: Relaxed — statistics counter (module note).
        self.tier_failed[tier.idx()].fetch_add(n as u64, Ordering::Relaxed);
    }

    pub fn record_batch(&self, samples: usize, service_s: f64) {
        // ordering: Relaxed — statistics counters (module note).
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.samples.fetch_add(samples as u64, Ordering::Relaxed);
        let mut b = self.batch_times.lock().unwrap();
        if b.len() < RESERVOIR_CAP {
            b.push(service_s);
        }
    }

    pub fn completed(&self) -> u64 {
        // ordering: Relaxed — statistics read (module note).
        self.completed.load(Ordering::Relaxed)
    }

    pub fn failed(&self) -> u64 {
        // ordering: Relaxed — statistics read (module note).
        self.failed.load(Ordering::Relaxed)
    }

    pub fn batches(&self) -> u64 {
        // ordering: Relaxed — statistics read (module note).
        self.batches.load(Ordering::Relaxed)
    }

    /// Mean samples per formed batch (batching effectiveness).
    pub fn mean_batch_size(&self) -> f64 {
        // ordering: Relaxed — statistics reads; the two counters may be
        // one event apart mid-race, fine for a mean (module note).
        let b = self.batches.load(Ordering::Relaxed);
        if b == 0 {
            0.0
        } else {
            self.samples.load(Ordering::Relaxed) as f64 / b as f64
        }
    }

    /// Latency summary over the reservoir.
    pub fn latency_summary(&self) -> crate::util::stats::Summary {
        crate::util::stats::Summary::of(&self.latencies.lock().unwrap())
    }

    pub fn batch_time_summary(&self) -> crate::util::stats::Summary {
        crate::util::stats::Summary::of(&self.batch_times.lock().unwrap())
    }

    /// Completed requests served at `tier`.
    pub fn tier_completed(&self, tier: Tier) -> u64 {
        // ordering: Relaxed — statistics read (module note).
        self.tier_completed[tier.idx()].load(Ordering::Relaxed)
    }

    /// Failed requests attributed to `tier`.
    pub fn tier_failed(&self, tier: Tier) -> u64 {
        // ordering: Relaxed — statistics read (module note).
        self.tier_failed[tier.idx()].load(Ordering::Relaxed)
    }

    /// Snapshot of the `tier` latency histogram (seconds, `le` buckets).
    pub fn tier_latency_histogram(&self, tier: Tier) -> Histogram {
        self.tier_hist[tier.idx()].lock().unwrap().clone()
    }

    /// Mean basis terms reduced per request at `tier` (0 when none).
    pub fn tier_mean_terms(&self, tier: Tier) -> f64 {
        let n = self.tier_completed(tier);
        if n == 0 {
            0.0
        } else {
            // ordering: Relaxed — statistics read (module note).
            self.tier_terms[tier.idx()].load(Ordering::Relaxed) as f64 / n as f64
        }
    }

    /// Record one formed batch's INT GEMM grid spend at `tier` (the
    /// batch forward is shared by all its requests — call once per
    /// batch, not per request), plus the plan ceiling the batch was
    /// served under (`None` when the plan carried no ceiling — full or
    /// uniform plans).
    pub fn record_batch_grid(&self, tier: Tier, grid_terms: usize, planned: Option<usize>) {
        let i = tier.idx();
        // ordering: Relaxed — statistics counters (module note).
        self.tier_grid_terms[i].fetch_add(grid_terms as u64, Ordering::Relaxed);
        self.tier_grid_batches[i].fetch_add(1, Ordering::Relaxed);
        if let Some(p) = planned {
            self.tier_planned_grid[i].fetch_add(p as u64, Ordering::Relaxed);
            self.tier_planned_batches[i].fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Mean INT GEMM grid terms executed per *batch forward* at `tier`
    /// — the layer-granularity budget's observable (0 for unmetered
    /// backends). Note: conv grid spend scales with the rows in a
    /// batch, so compare tiers under similar batch shapes.
    pub fn tier_mean_grid_terms(&self, tier: Tier) -> f64 {
        // ordering: Relaxed — statistics reads (module note).
        let n = self.tier_grid_batches[tier.idx()].load(Ordering::Relaxed);
        if n == 0 {
            0.0
        } else {
            self.tier_grid_terms[tier.idx()].load(Ordering::Relaxed) as f64 / n as f64
        }
    }

    /// Mean *planned* grid ceiling per batch at `tier` (0 when no
    /// plan-carrying batch was served) — what the controller allocated,
    /// in single-forward pair units. Not directly comparable to
    /// [`Metrics::tier_mean_grid_terms`]: executed spend scales with
    /// prefix workers and conv image rows, the ceiling does not.
    pub fn tier_mean_planned_grid_terms(&self, tier: Tier) -> f64 {
        // ordering: Relaxed — statistics reads (module note).
        let n = self.tier_planned_batches[tier.idx()].load(Ordering::Relaxed);
        if n == 0 {
            0.0
        } else {
            self.tier_planned_grid[tier.idx()].load(Ordering::Relaxed) as f64 / n as f64
        }
    }

    /// Latency summary for one tier.
    pub fn tier_latency_summary(&self, tier: Tier) -> crate::util::stats::Summary {
        crate::util::stats::Summary::of(&self.tier_latencies[tier.idx()].lock().unwrap())
    }

    /// p99 request latency at `tier` over the whole reservoir (seconds;
    /// 0 when the tier served nothing) — the long-horizon view of the
    /// observable the per-tier SLO loop targets. The controller's own
    /// windowed digest
    /// ([`TermController::tier_p99`](crate::qos::TermController::tier_p99))
    /// sees the same latencies but forgets each window once a pressure
    /// decision consumes it.
    pub fn tier_p99(&self, tier: Tier) -> f64 {
        self.tier_latency_summary(tier).p99
    }

    /// Worst estimated precision loss (max-residual) served at `tier`;
    /// 0 when the controller never reported an estimate.
    pub fn tier_est_loss(&self, tier: Tier) -> f64 {
        self.tier_loss.lock().unwrap()[tier.idx()]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let m = Metrics::new();
        m.record_completed(0.001);
        m.record_completed(0.003);
        m.record_failed(2);
        m.record_batch(8, 0.002);
        m.record_batch(4, 0.004);
        assert_eq!(m.completed(), 2);
        assert_eq!(m.failed(), 2);
        assert_eq!(m.batches(), 2);
        assert!((m.mean_batch_size() - 6.0).abs() < 1e-9);
        let s = m.latency_summary();
        assert_eq!(s.n, 2);
        assert!((s.mean - 0.002).abs() < 1e-9);
    }

    #[test]
    fn per_tier_accounting() {
        let m = Metrics::new();
        m.record_completed_tier(Tier::Exact, 0.004, 8, None);
        m.record_completed_tier(Tier::Throughput, 0.001, 2, Some(0.01));
        m.record_completed_tier(Tier::Throughput, 0.002, 4, Some(0.002));
        m.record_batch_grid(Tier::Exact, 64, None);
        m.record_batch_grid(Tier::Throughput, 6, Some(8));
        m.record_batch_grid(Tier::Throughput, 10, Some(12));
        assert_eq!(m.completed(), 3);
        assert_eq!(m.tier_completed(Tier::Exact), 1);
        assert_eq!(m.tier_completed(Tier::Throughput), 2);
        assert_eq!(m.tier_completed(Tier::BestEffort), 0);
        assert!((m.tier_mean_terms(Tier::Throughput) - 3.0).abs() < 1e-9);
        assert!((m.tier_mean_terms(Tier::Exact) - 8.0).abs() < 1e-9);
        assert_eq!(m.tier_mean_terms(Tier::Balanced), 0.0);
        assert!((m.tier_mean_grid_terms(Tier::Throughput) - 8.0).abs() < 1e-9);
        assert!((m.tier_mean_grid_terms(Tier::Exact) - 64.0).abs() < 1e-9);
        assert_eq!(m.tier_mean_grid_terms(Tier::Balanced), 0.0);
        // planned ceilings accumulate only for plan-carrying batches
        assert!((m.tier_mean_planned_grid_terms(Tier::Throughput) - 10.0).abs() < 1e-9);
        assert_eq!(m.tier_mean_planned_grid_terms(Tier::Exact), 0.0);
        // worst loss wins
        assert!((m.tier_est_loss(Tier::Throughput) - 0.01).abs() < 1e-9);
        assert_eq!(m.tier_est_loss(Tier::Exact), 0.0);
        let s = m.tier_latency_summary(Tier::Throughput);
        assert_eq!(s.n, 2);
        // the SLO loop's observable: per-tier p99 over the reservoir
        assert!((m.tier_p99(Tier::Throughput) - s.p99).abs() < 1e-12);
        assert_eq!(m.tier_p99(Tier::BestEffort), 0.0);
    }

    #[test]
    fn histograms_and_failed_tiers_track_exposition_inputs() {
        let m = Metrics::new();
        m.record_completed_tier(Tier::Exact, 0.0004, 8, None);
        m.record_completed_tier(Tier::Exact, 0.02, 8, None);
        m.record_failed_tier(Tier::BestEffort, 3);
        let h = m.tier_latency_histogram(Tier::Exact);
        assert_eq!(h.count(), 2);
        assert!((h.sum() - 0.0204).abs() < 1e-9);
        // both observations fall inside the finite latency ladder
        assert_eq!(*h.bucket_counts().last().unwrap(), 0);
        assert_eq!(m.tier_latency_histogram(Tier::Balanced).count(), 0);
        // tier failure attribution also feeds the aggregate counter
        assert_eq!(m.tier_failed(Tier::BestEffort), 3);
        assert_eq!(m.tier_failed(Tier::Exact), 0);
        assert_eq!(m.failed(), 3);
    }
}
