//! Serving metrics: lock-free counters + a latency reservoir.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// Coordinator-wide metrics.
#[derive(Debug, Default)]
pub struct Metrics {
    completed: AtomicU64,
    failed: AtomicU64,
    batches: AtomicU64,
    samples: AtomicU64,
    /// request latencies (seconds); reservoir capped to keep memory flat
    latencies: Mutex<Vec<f64>>,
    /// batch service times (seconds)
    batch_times: Mutex<Vec<f64>>,
}

const RESERVOIR_CAP: usize = 100_000;

impl Metrics {
    pub fn new() -> Metrics {
        Metrics::default()
    }

    pub fn record_completed(&self, latency_s: f64) {
        self.completed.fetch_add(1, Ordering::Relaxed);
        let mut l = self.latencies.lock().unwrap();
        if l.len() < RESERVOIR_CAP {
            l.push(latency_s);
        }
    }

    pub fn record_failed(&self, n: usize) {
        self.failed.fetch_add(n as u64, Ordering::Relaxed);
    }

    pub fn record_batch(&self, samples: usize, service_s: f64) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.samples.fetch_add(samples as u64, Ordering::Relaxed);
        let mut b = self.batch_times.lock().unwrap();
        if b.len() < RESERVOIR_CAP {
            b.push(service_s);
        }
    }

    pub fn completed(&self) -> u64 {
        self.completed.load(Ordering::Relaxed)
    }

    pub fn failed(&self) -> u64 {
        self.failed.load(Ordering::Relaxed)
    }

    pub fn batches(&self) -> u64 {
        self.batches.load(Ordering::Relaxed)
    }

    /// Mean samples per formed batch (batching effectiveness).
    pub fn mean_batch_size(&self) -> f64 {
        let b = self.batches.load(Ordering::Relaxed);
        if b == 0 {
            0.0
        } else {
            self.samples.load(Ordering::Relaxed) as f64 / b as f64
        }
    }

    /// Latency summary over the reservoir.
    pub fn latency_summary(&self) -> crate::util::stats::Summary {
        crate::util::stats::Summary::of(&self.latencies.lock().unwrap())
    }

    pub fn batch_time_summary(&self) -> crate::util::stats::Summary {
        crate::util::stats::Summary::of(&self.batch_times.lock().unwrap())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let m = Metrics::new();
        m.record_completed(0.001);
        m.record_completed(0.003);
        m.record_failed(2);
        m.record_batch(8, 0.002);
        m.record_batch(4, 0.004);
        assert_eq!(m.completed(), 2);
        assert_eq!(m.failed(), 2);
        assert_eq!(m.batches(), 2);
        assert!((m.mean_batch_size() - 6.0).abs() < 1e-9);
        let s = m.latency_summary();
        assert_eq!(s.n, 2);
        assert!((s.mean - 0.002).abs() < 1e-9);
    }
}
