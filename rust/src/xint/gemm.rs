//! Eq. 3 — tensor-multiplication low-bit expansion:
//! `WA = Σ_{i,j} scale_{W,i} scale_{A,j} W̃_i Ã_j`.
//!
//! The hot kernel is [`int_gemm_a_bt`]: an integer matmul with `i32`
//! accumulation (the CPU stand-in for the INT8/INT4 units of the paper's
//! A800). The rank-1 `M_nsy` terms use the §4 `(M·oneᵀ)·one` trick and
//! cost O(n²); the sparse `M_sa` terms use a COO kernel proportional to
//! nnz. `xint_linear_forward` assembles the full Eq. 3 sum for a linear
//! layer `y = x Wᵀ` where both operands are series expansions.

use super::expansion::{ExpandConfig, SeriesExpansion};
use crate::tensor::{IntTensor, Tensor};

/// A weight matrix `(out, in)` pre-expanded at load time (PTQ happens once;
/// only activations are expanded on the request path).
#[derive(Clone, Debug)]
pub struct ExpandedWeight {
    pub exp: SeriesExpansion,
    pub out_dim: usize,
    pub in_dim: usize,
    /// per-plane row sums `Σ_k W̃_i[o,k]` — precomputed for the rank-1
    /// activation-bias (`A_nsy`) terms, O(out) per use instead of O(out·in)
    pub plane_row_sums: Vec<Vec<i64>>,
    /// row sums of the dense FP weight (bias and sparse cross terms)
    pub fp_row_sums: Vec<f32>,
    /// dense FP reconstruction of the *sparse* part only (usually empty)
    pub sparse_dense: Option<Tensor>,
}

impl ExpandedWeight {
    /// Expand `w` (out, in) with the given config (per-channel axis 0 is
    /// the natural choice for weights).
    pub fn new(w: &Tensor, cfg: &ExpandConfig) -> ExpandedWeight {
        assert_eq!(w.shape().rank(), 2, "ExpandedWeight wants (out, in)");
        let (out_dim, in_dim) = (w.dims()[0], w.dims()[1]);
        let exp = SeriesExpansion::expand(w, cfg);
        let plane_row_sums = exp
            .planes
            .iter()
            .map(|p| {
                (0..out_dim)
                    .map(|o| p.data()[o * in_dim..(o + 1) * in_dim].iter().map(|&v| v as i64).sum())
                    .collect()
            })
            .collect();
        let fp_row_sums = (0..out_dim)
            .map(|o| w.data()[o * in_dim..(o + 1) * in_dim].iter().sum())
            .collect();
        let sparse_dense = if exp.sparse.nnz() > 0 { Some(exp.sparse.to_dense()) } else { None };
        ExpandedWeight { exp, out_dim, in_dim, plane_row_sums, fp_row_sums, sparse_dense }
    }

    /// Number of INT weight terms `k`.
    pub fn terms(&self) -> usize {
        self.exp.planes.len()
    }
}

/// Integer GEMM `C = A × Bᵀ` with i32 accumulation: A `(m,k)`, B `(n,k)`.
///
/// Values are INT(X) planes so every product fits comfortably in i32 for
/// X ≤ 12 and k ≤ 2^named; accumulate in i64 when that could overflow.
pub fn int_gemm_a_bt(a: &IntTensor, b: &IntTensor) -> Vec<i64> {
    let (m, k) = (a.dims()[0], a.dims()[1]);
    let (n, k2) = (b.dims()[0], b.dims()[1]);
    assert_eq!(k, k2, "int_gemm inner dims {k} vs {k2}");
    let ad = a.data();
    let bd = b.data();
    let mut c = vec![0i64; m * n];
    for i in 0..m {
        let arow = &ad[i * k..(i + 1) * k];
        let crow = &mut c[i * n..(i + 1) * n];
        for (j, cv) in crow.iter_mut().enumerate() {
            *cv = int_dot(arow, &bd[j * k..(j + 1) * k]);
        }
    }
    c
}

/// i32 dot product with chunked i64 folding — branch-free inner loop that
/// autovectorizes (§Perf iteration 1: replaced a per-element `% 256` fold,
/// which defeated vectorization and ran ≈0.7× of f32 at large shapes).
///
/// Safety of the i32 partials: |v| ≤ 2^11 ⇒ product ≤ 2^22 and a
/// 256-chunk sums to ≤ 2^30 < i32::MAX. Basis planes use X ≤ 8 in
/// practice; debug builds assert the envelope.
#[inline]
pub fn int_dot(a: &[i32], b: &[i32]) -> i64 {
    debug_assert_eq!(a.len(), b.len());
    debug_assert!(a.iter().all(|&v| v.abs() <= 1 << 11));
    const CHUNK: usize = 256;
    let mut acc: i64 = 0;
    let mut ai = a.chunks_exact(CHUNK);
    let mut bi = b.chunks_exact(CHUNK);
    for (ca, cb) in (&mut ai).zip(&mut bi) {
        let mut partial: i32 = 0;
        for (&x, &y) in ca.iter().zip(cb) {
            partial += x * y;
        }
        acc += partial as i64;
    }
    let mut partial: i32 = 0;
    for (&x, &y) in ai.remainder().iter().zip(bi.remainder()) {
        partial += x * y;
    }
    acc + partial as i64
}

/// §Perf iteration 2: fused scaled accumulation `Y += s_a · diag(s_w) ·
/// (A × Bᵀ)` — one pass per (i, j) term pair, no i64 intermediate buffer.
/// `w_scales` is per-out-channel (len n) or a single broadcast scale.
pub fn int_gemm_scaled_into(
    a: &IntTensor,
    b: &IntTensor,
    w_scales: &[f32],
    s_a: f32,
    y: &mut [f32],
) {
    let (m, k) = (a.dims()[0], a.dims()[1]);
    let (n, k2) = (b.dims()[0], b.dims()[1]);
    assert_eq!(k, k2, "int_gemm inner dims {k} vs {k2}");
    assert_eq!(y.len(), m * n);
    let per_ch = w_scales.len() > 1;
    let ad = a.data();
    let bd = b.data();
    for i in 0..m {
        let arow = &ad[i * k..(i + 1) * k];
        let yrow = &mut y[i * n..(i + 1) * n];
        for (j, yv) in yrow.iter_mut().enumerate() {
            let s_w = if per_ch { w_scales[j] } else { w_scales[0] };
            *yv += s_a * s_w * int_dot(arow, &bd[j * k..(j + 1) * k]) as f32;
        }
    }
}

/// Full Eq. 3 forward for a linear layer: `y = x Wᵀ` with `x` expanded
/// on the fly at `act_cfg` and `W` pre-expanded.
///
/// Decomposition (weights: bias_w per out-channel over `one` row; acts:
/// bias_a scalar over `one`):
/// `y[b,o] = Σ_{i,j} s_wi[o] s_aj (Ã_j W̃_iᵀ)[b,o]`      (INT GEMM, k·t terms)
///        `+ bias_a · Σ_i s_wi[o] rowsum(W̃_i)[o]`        (rank-1, O(out))
///        `+ bias_w[o] · Σ_j s_aj rowsum(Ã_j)[b]`         (rank-1, O(batch))
///        `+ bias_a · bias_w[o] · in_dim`                  (constant)
///        `+ sparse cross terms (exact, via dense fallback on A_sa/W_sa)`.
pub fn xint_linear_forward(x: &Tensor, w: &ExpandedWeight, act_cfg: &ExpandConfig) -> Tensor {
    assert_eq!(x.shape().rank(), 2);
    assert_eq!(x.dims()[1], w.in_dim, "in_dim mismatch");
    let a_exp = SeriesExpansion::expand(x, act_cfg);
    xint_linear_forward_pre(&a_exp, x, w)
}

/// Same as [`xint_linear_forward`] but with the activation expansion
/// supplied by the caller (the coordinator expands once and fans out).
pub fn xint_linear_forward_pre(
    a_exp: &SeriesExpansion,
    x: &Tensor,
    w: &ExpandedWeight,
) -> Tensor {
    let (batch, in_dim) = (x.dims()[0], x.dims()[1]);
    let out_dim = w.out_dim;
    let mut y = Tensor::zeros(&[batch, out_dim]);
    let yd = y.data_mut();

    // --- INT × INT terms (the k·t low-bit GEMMs of Figure 2's red grid)
    // §Perf iteration 2: fused scale application inside the GEMM — one
    // pass per (i, j) pair, no i64 intermediate, no scale re-derivation.
    for (i, wplane) in w.exp.planes.iter().enumerate() {
        for (j, aplane) in a_exp.planes.iter().enumerate() {
            let s_aj = a_exp.scales[j][0];
            if s_aj == 0.0 {
                continue;
            }
            int_gemm_scaled_into(aplane, wplane, &w.exp.scales[i], s_aj, yd);
        }
    }

    // --- activation zero-point × INT weight planes: bias_a · rowsum(W̃_i)
    let bias_a = a_exp.bias[0];
    if bias_a != 0.0 {
        let pcs = &w.plane_row_sums;
        for (i, rs) in pcs.iter().enumerate() {
            let pc = w.exp.scales[i].len() > 1;
            for o in 0..out_dim {
                let s_wi = if pc { w.exp.scales[i][o] } else { w.exp.scales[i][0] };
                let add = bias_a * s_wi * rs[o] as f32;
                for b in 0..batch {
                    yd[b * out_dim + o] += add;
                }
            }
        }
        // activation zero-point × weight sparse part
        if let Some(sd) = &w.sparse_dense {
            for o in 0..out_dim {
                let add: f32 = bias_a * sd.row(o).iter().sum::<f32>();
                for b in 0..batch {
                    yd[b * out_dim + o] += add;
                }
            }
        }
        // activation zero-point × weight zero-point handled below via
        // fp_row_sums? No: keep exact decomposition — bias_w term covers it.
    }

    // --- weight zero-point (asymmetric weights) × reconstructed activation:
    // bias_w[o] · Σ_k recon(A)[b,k]. The row sum of recon(A) is assembled
    // from cheap precomputable pieces — bias_a·in_dim, per-plane row sums,
    // and the sparse row sums — never from a dense reconstruction.
    if w.exp.bias.iter().any(|&b| b != 0.0) {
        let per_ch = w.exp.bias.len() > 1;
        let mut arow_sums = vec![bias_a * in_dim as f32; batch];
        for (j, aplane) in a_exp.planes.iter().enumerate() {
            let s_aj = a_exp.scales[j][0];
            if s_aj == 0.0 {
                continue;
            }
            for (b, acc) in arow_sums.iter_mut().enumerate() {
                let rs: i64 =
                    aplane.data()[b * in_dim..(b + 1) * in_dim].iter().map(|&v| v as i64).sum();
                *acc += s_aj * rs as f32;
            }
        }
        for (&idx, &v) in a_exp.sparse.indices.iter().zip(&a_exp.sparse.values) {
            arow_sums[idx / in_dim] += v;
        }
        for (b, &xs) in arow_sums.iter().enumerate() {
            for o in 0..out_dim {
                let bw = if per_ch { w.exp.bias[o] } else { w.exp.bias[0] };
                if bw != 0.0 {
                    yd[b * out_dim + o] += bw * xs;
                }
            }
        }
    }

    // --- sparse A_sa × W terms and sparse W_sa × Ã terms
    // A_sa: activation saturation residual (exact): y += A_sa · Wᵀ_fp
    if a_exp.sparse.nnz() > 0 {
        // reconstruct W's dense non-bias part lazily? Use full precision
        // weight reconstruction = planes + sparse (bias handled above).
        // Cheaper: A_sa is very sparse — loop nnz.
        let wrec = w.exp.reconstruct(); // (out, in) incl. bias; subtract bias later
        let per_ch = w.exp.bias.len() > 1;
        for (&idx, &v) in a_exp.sparse.indices.iter().zip(&a_exp.sparse.values) {
            let b = idx / w.in_dim;
            let k = idx % w.in_dim;
            for o in 0..out_dim {
                let bw = if per_ch { w.exp.bias[o] } else { w.exp.bias[0] };
                // wrec includes bias_w; the bias_w × full-x term above
                // already paired bias_w with the full x (which includes
                // A_sa), so exclude it here.
                yd[b * out_dim + o] += v * (wrec.data()[o * w.in_dim + k] - bw);
            }
        }
    }
    // W_sa × Ã terms: pair the weight's sparse residual with the expanded
    // activation (the INT grid used only the planes).
    if let Some(sd) = &w.sparse_dense {
        // a_expanded dense (without bias/sparse: those were paired above)
        let mut arec = Tensor::zeros(&[batch, in_dim]);
        for t in 0..a_exp.planes.len() {
            let s = a_exp.scales[t][0];
            if s == 0.0 {
                continue;
            }
            for (dst, &src) in arec.data_mut().iter_mut().zip(a_exp.planes[t].data()) {
                *dst += s * src as f32;
            }
        }
        let contrib = crate::tensor::matmul_a_bt(&arec, sd);
        for (dst, &src) in yd.iter_mut().zip(contrib.data()) {
            *dst += src;
        }
    }

    y
}

/// Reference: dequantize both expansions densely and multiply in FP —
/// used by tests to pin the decomposed fast path to the definition.
pub fn xint_linear_reference(x: &Tensor, w: &ExpandedWeight, act_cfg: &ExpandConfig) -> Tensor {
    let a_exp = SeriesExpansion::expand(x, act_cfg);
    let a_rec = a_exp.reconstruct();
    let w_rec = w.exp.reconstruct();
    crate::tensor::matmul_a_bt(&a_rec, &w_rec)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Rng;
    use crate::xint::quantizer::{Clip, Symmetry};
    use crate::xint::BitSpec;

    fn close(a: &Tensor, b: &Tensor, tol: f32) {
        assert_eq!(a.dims(), b.dims());
        for (x, y) in a.data().iter().zip(b.data()) {
            assert!((x - y).abs() <= tol * (1.0 + x.abs().max(y.abs())), "{x} vs {y}");
        }
    }

    #[test]
    fn int_gemm_matches_f32_gemm() {
        let mut rng = Rng::seed(31);
        let a = IntTensor::from_vec(&[4, 9], (0..36).map(|_| rng.below(17) as i32 - 8).collect());
        let b = IntTensor::from_vec(&[5, 9], (0..45).map(|_| rng.below(17) as i32 - 8).collect());
        let c = int_gemm_a_bt(&a, &b);
        let cf = crate::tensor::matmul_a_bt(&a.to_f32(), &b.to_f32());
        for (i, &v) in c.iter().enumerate() {
            assert_eq!(v as f32, cf.data()[i]);
        }
    }

    #[test]
    fn int_gemm_large_values_no_overflow() {
        // INT12-ish planes with long K: exercise the i64 fold path
        let k = 5000;
        let a = IntTensor::from_vec(&[1, k], vec![2047; k]);
        let b = IntTensor::from_vec(&[1, k], vec![2047; k]);
        let c = int_gemm_a_bt(&a, &b);
        assert_eq!(c[0], 2047i64 * 2047 * k as i64);
    }

    /// The decomposed fast path must equal the dense dequantize-then-matmul
    /// reference bit-for-bit (same float ops modulo association tolerance).
    #[test]
    fn forward_matches_reference_all_variants() {
        let mut rng = Rng::seed(33);
        let x = Tensor::randn(&[3, 16], 1.0, &mut rng);
        let w_raw = Tensor::randn(&[5, 16], 0.5, &mut rng);
        for sym in [Symmetry::Symmetric, Symmetry::Asymmetric] {
            for clip in [Clip::None, Clip::Laplace] {
                for ch_axis in [None, Some(0)] {
                    let wcfg = ExpandConfig {
                        bits: BitSpec::int(4),
                        terms: 2,
                        symmetry: sym,
                        clip,
                        channel_axis: ch_axis,
                    };
                    let acfg = ExpandConfig {
                        bits: BitSpec::int(4),
                        terms: 3,
                        symmetry: sym,
                        clip,
                        channel_axis: None,
                    };
                    let w = ExpandedWeight::new(&w_raw, &wcfg);
                    let got = xint_linear_forward(&x, &w, &acfg);
                    let want = xint_linear_reference(&x, &w, &acfg);
                    close(&got, &want, 2e-4);
                }
            }
        }
    }

    #[test]
    fn forward_converges_to_fp_with_terms() {
        let mut rng = Rng::seed(35);
        let x = Tensor::randn(&[4, 32], 1.0, &mut rng);
        let w_raw = Tensor::randn(&[8, 32], 0.3, &mut rng);
        let fp = crate::tensor::matmul_a_bt(&x, &w_raw);
        let mut errs = Vec::new();
        for terms in 1..=4 {
            let wcfg = ExpandConfig::weights(BitSpec::int(4), 2);
            let acfg = ExpandConfig::symmetric(BitSpec::int(4), terms);
            let w = ExpandedWeight::new(&w_raw, &wcfg);
            let y = xint_linear_forward(&x, &w, &acfg);
            errs.push(fp.sub(&y).max_abs());
        }
        assert!(errs[3] < errs[0] / 10.0, "no convergence: {errs:?}");
    }

    #[test]
    fn w8a8_single_term_is_tight() {
        let mut rng = Rng::seed(36);
        let x = Tensor::randn(&[2, 64], 1.0, &mut rng);
        let w_raw = Tensor::randn(&[4, 64], 0.2, &mut rng);
        let fp = crate::tensor::matmul_a_bt(&x, &w_raw);
        let w = ExpandedWeight::new(&w_raw, &ExpandConfig::symmetric(BitSpec::int(8), 1));
        let y = xint_linear_forward(&x, &w, &ExpandConfig::symmetric(BitSpec::int(8), 1));
        let rel = fp.sub(&y).norm() / fp.norm();
        assert!(rel < 0.02, "W8A8 relative error {rel}");
    }

    #[test]
    fn row_sums_precompute_is_consistent() {
        let mut rng = Rng::seed(37);
        let w_raw = Tensor::randn(&[6, 10], 1.0, &mut rng);
        let w = ExpandedWeight::new(&w_raw, &ExpandConfig::symmetric(BitSpec::int(4), 2));
        for (i, plane) in w.exp.planes.iter().enumerate() {
            for o in 0..6 {
                let s: i64 = plane.data()[o * 10..(o + 1) * 10].iter().map(|&v| v as i64).sum();
                assert_eq!(s, w.plane_row_sums[i][o]);
            }
        }
    }
}
