//! Eq. 3 — tensor-multiplication low-bit expansion:
//! `WA = Σ_{i,j} scale_{W,i} scale_{A,j} W̃_i Ã_j`.
//!
//! The hot kernel is [`int_gemm_a_bt`]: an integer matmul with `i32`
//! accumulation (the CPU stand-in for the INT8/INT4 units of the paper's
//! A800). The rank-1 `M_nsy` terms use the §4 `(M·oneᵀ)·one` trick and
//! cost O(n²); the sparse `M_sa` terms use a COO kernel proportional to
//! nnz. `xint_linear_forward` assembles the full Eq. 3 sum for a linear
//! layer `y = x Wᵀ` where both operands are series expansions;
//! [`xint_linear_forward_budgeted`] is the same forward under a runtime
//! [`TermBudget`], executing the `(i, j)` grid largest-scale-first so
//! any truncation prefix is the best available approximation.

use super::budget::TermBudget;
use super::expansion::{ExpandConfig, SeriesExpansion};
use super::kernel::{self, GridRun, PackedPlane};
use crate::tensor::{IntTensor, Tensor};
use crate::util::sync::{Arc, OnceLock};

/// A weight matrix `(out, in)` pre-expanded at load time (PTQ happens once;
/// only activations are expanded on the request path).
#[derive(Clone, Debug)]
pub struct ExpandedWeight {
    pub exp: SeriesExpansion,
    pub out_dim: usize,
    pub in_dim: usize,
    /// per-plane row sums `Σ_k W̃_i[o,k]` — precomputed for the rank-1
    /// activation-bias (`A_nsy`) terms, O(out) per use instead of O(out·in)
    pub plane_row_sums: Vec<Vec<i64>>,
    /// basis planes packed to i8 once at load; `None` for a plane with
    /// a value outside the [`kernel::PACK_MAX_ABS`] envelope (an X = 8
    /// saturating plane), which routes its grid cells to the scalar
    /// kernel
    pub packed: Vec<Option<Arc<PackedPlane>>>,
    /// per-plane scale vectors behind `Arc` so a row-parallel kernel
    /// run can share them without cloning per layer call
    pub scale_arcs: Vec<Arc<Vec<f32>>>,
    /// dense FP reconstruction of the *sparse* part only (usually empty)
    pub sparse_dense: Option<Tensor>,
    /// dense FP reconstruction of the whole expansion (incl. bias),
    /// built once on first use: the `A_sa` sparse path needs it, and
    /// with Laplace-clipped activations that path runs on every request
    recon: OnceLock<Tensor>,
}

impl ExpandedWeight {
    /// Expand `w` (out, in) with the given config (per-channel axis 0 is
    /// the natural choice for weights).
    pub fn new(w: &Tensor, cfg: &ExpandConfig) -> ExpandedWeight {
        assert_eq!(w.shape().rank(), 2, "ExpandedWeight wants (out, in)");
        let (out_dim, in_dim) = (w.dims()[0], w.dims()[1]);
        let exp = SeriesExpansion::expand(w, cfg);
        let plane_row_sums = exp
            .planes
            .iter()
            .map(|p| {
                (0..out_dim)
                    .map(|o| p.data()[o * in_dim..(o + 1) * in_dim].iter().map(|&v| v as i64).sum())
                    .collect()
            })
            .collect();
        let sparse_dense = if exp.sparse.nnz() > 0 { Some(exp.sparse.to_dense()) } else { None };
        // tentpole: weight planes pack to i8 once here at load time —
        // the request path only packs activations
        let packed = exp.planes.iter().map(|p| PackedPlane::pack(p).map(Arc::new)).collect();
        let scale_arcs = exp.scales.iter().map(|s| Arc::new(s.clone())).collect();
        let recon = OnceLock::new();
        ExpandedWeight {
            exp,
            out_dim,
            in_dim,
            plane_row_sums,
            packed,
            scale_arcs,
            sparse_dense,
            recon,
        }
    }

    /// Number of INT weight terms `k`.
    pub fn terms(&self) -> usize {
        self.exp.planes.len()
    }

    /// Cached dense reconstruction of the expansion (incl. bias).
    pub fn reconstructed(&self) -> &Tensor {
        self.recon.get_or_init(|| self.exp.reconstruct())
    }
}

/// The single INT-dot envelope every integer kernel in this crate
/// shares: basis-plane values must satisfy `|v| ≤ INT_DOT_MAX_ABS`
/// (= 2^11, i.e. planes up to X = 12, whose inclusive symmetric
/// half-range is exactly 2^11). Then a product is ≤ 2^22 and a
/// 256-element partial sums to ≤ 2^30 < `i32::MAX`, so the chunked
/// i32 accumulation in [`int_dot`] is exact. The i8 fast path narrows
/// this further ([`kernel::PACK_MAX_ABS`] cites this constant as its
/// outer bound); planes inside this envelope but outside that one
/// take the scalar path here.
pub const INT_DOT_MAX_ABS: i32 = 1 << 11;

/// Integer GEMM `C = A × Bᵀ` with i32 accumulation: A `(m,k)`, B `(n,k)`.
///
/// Values are INT(X) planes inside the [`INT_DOT_MAX_ABS`] envelope
/// (X ≤ 12); the inner loop folds 256-element i32 partials into an i64
/// accumulator, so any inner dimension `k` is overflow-safe.
pub fn int_gemm_a_bt(a: &IntTensor, b: &IntTensor) -> Vec<i64> {
    let (m, k) = (a.dims()[0], a.dims()[1]);
    let (n, k2) = (b.dims()[0], b.dims()[1]);
    assert_eq!(k, k2, "int_gemm inner dims {k} vs {k2}");
    let ad = a.data();
    let bd = b.data();
    let mut c = vec![0i64; m * n];
    for i in 0..m {
        let arow = &ad[i * k..(i + 1) * k];
        let crow = &mut c[i * n..(i + 1) * n];
        for (j, cv) in crow.iter_mut().enumerate() {
            *cv = int_dot(arow, &bd[j * k..(j + 1) * k]);
        }
    }
    c
}

/// i32 dot product with chunked i64 folding — branch-free inner loop that
/// autovectorizes (§Perf iteration 1: replaced a per-element `% 256` fold,
/// which defeated vectorization and ran ≈0.7× of f32 at large shapes).
///
/// Exactness rests on the [`INT_DOT_MAX_ABS`] envelope (stated and
/// bounded there); debug builds assert it on both operands. Basis
/// planes use X ≤ 8 in practice, well inside it.
/// Debug-assert every value sits inside the ±`bound` envelope — the
/// shared guard for [`int_dot`]'s operands and `PackedPlane::pack`'s
/// input plane, so the two sites can't drift apart.
#[inline]
pub fn debug_assert_envelope(vals: &[i32], bound: i32, what: &str) {
    debug_assert!(
        vals.iter().all(|&v| v.abs() <= bound),
        "{what}: value outside the ±{bound} envelope"
    );
}

#[inline]
pub fn int_dot(a: &[i32], b: &[i32]) -> i64 {
    debug_assert_eq!(a.len(), b.len());
    debug_assert_envelope(a, INT_DOT_MAX_ABS, "int_dot lhs");
    debug_assert_envelope(b, INT_DOT_MAX_ABS, "int_dot rhs");
    const CHUNK: usize = 256;
    let mut acc: i64 = 0;
    let mut ai = a.chunks_exact(CHUNK);
    let mut bi = b.chunks_exact(CHUNK);
    for (ca, cb) in (&mut ai).zip(&mut bi) {
        let mut partial: i32 = 0;
        for (&x, &y) in ca.iter().zip(cb) {
            partial += x * y;
        }
        acc += partial as i64;
    }
    let mut partial: i32 = 0;
    for (&x, &y) in ai.remainder().iter().zip(bi.remainder()) {
        partial += x * y;
    }
    acc + partial as i64
}

/// §Perf iteration 2: fused scaled accumulation `Y += s_a · diag(s_w) ·
/// (A × Bᵀ)` — one pass per (i, j) term pair, no i64 intermediate buffer.
/// `w_scales` is per-out-channel (len n) or a single broadcast scale.
pub fn int_gemm_scaled_into(
    a: &IntTensor,
    b: &IntTensor,
    w_scales: &[f32],
    s_a: f32,
    y: &mut [f32],
) {
    let (m, k) = (a.dims()[0], a.dims()[1]);
    let (n, k2) = (b.dims()[0], b.dims()[1]);
    assert_eq!(k, k2, "int_gemm inner dims {k} vs {k2}");
    assert_eq!(y.len(), m * n);
    let per_ch = w_scales.len() > 1;
    let ad = a.data();
    let bd = b.data();
    for i in 0..m {
        let arow = &ad[i * k..(i + 1) * k];
        let yrow = &mut y[i * n..(i + 1) * n];
        for (j, yv) in yrow.iter_mut().enumerate() {
            let s_w = if per_ch { w_scales[j] } else { w_scales[0] };
            *yv += s_a * s_w * int_dot(arow, &bd[j * k..(j + 1) * k]) as f32;
        }
    }
}

/// Full Eq. 3 forward for a linear layer: `y = x Wᵀ` with `x` expanded
/// on the fly at `act_cfg` and `W` pre-expanded.
///
/// Decomposition (weights: bias_w per out-channel over `one` row; acts:
/// bias_a scalar over `one`):
/// `y[b,o] = Σ_{i,j} s_wi[o] s_aj (Ã_j W̃_iᵀ)[b,o]`      (INT GEMM, k·t terms)
///        `+ bias_a · Σ_i s_wi[o] rowsum(W̃_i)[o]`        (rank-1, O(out))
///        `+ bias_w[o] · Σ_j s_aj rowsum(Ã_j)[b]`         (rank-1, O(batch))
///        `+ bias_a · bias_w[o] · in_dim`                  (constant)
///        `+ sparse cross terms (exact, via dense fallback on A_sa/W_sa)`.
pub fn xint_linear_forward(x: &Tensor, w: &ExpandedWeight, act_cfg: &ExpandConfig) -> Tensor {
    assert_eq!(x.shape().rank(), 2);
    assert_eq!(x.dims()[1], w.in_dim, "in_dim mismatch");
    let a_exp = SeriesExpansion::expand(x, act_cfg);
    xint_linear_forward_pre(&a_exp, x, w)
}

/// [`xint_linear_forward`] under a runtime [`TermBudget`]: the `(i, j)`
/// GEMM grid is capped per axis and optionally in total, taking pairs in
/// descending `s_wi · s_aj` order (largest contribution first — the
/// Abelian prefix argument one level below the worker pool). Activations
/// are expanded only to the budgeted term count, so a low budget saves
/// both expansion and GEMM work. A budget that covers the full grid runs
/// the legacy natural-order loop and is bit-identical to
/// [`xint_linear_forward`]. Returns the output and the number of INT
/// GEMM terms actually executed.
pub fn xint_linear_forward_budgeted(
    x: &Tensor,
    w: &ExpandedWeight,
    act_cfg: &ExpandConfig,
    budget: &TermBudget,
) -> (Tensor, usize) {
    assert_eq!(x.shape().rank(), 2);
    assert_eq!(x.dims()[1], w.in_dim, "in_dim mismatch");
    // the closed-form planes are prefix-stable: expanding at a_cap terms
    // yields exactly the first a_cap planes of the full expansion
    let (_, a_cap) = budget.clamp_to(w.terms(), act_cfg.terms);
    let a_exp = SeriesExpansion::expand(x, &act_cfg.with_terms(a_cap));
    xint_linear_forward_pre_budgeted(&a_exp, x, w, budget)
}

/// Same as [`xint_linear_forward`] but with the activation expansion
/// supplied by the caller (the coordinator expands once and fans out).
pub fn xint_linear_forward_pre(
    a_exp: &SeriesExpansion,
    x: &Tensor,
    w: &ExpandedWeight,
) -> Tensor {
    xint_linear_forward_pre_budgeted(a_exp, x, w, &TermBudget::full()).0
}

/// [`xint_linear_forward_pre`] under a [`TermBudget`]. With a full
/// budget the INT grid runs in the legacy natural order (bit-identical
/// output); a truncating budget orders the capped grid by scale product
/// and stops at the grid cap — or earlier, at the §5.3 in-grid anytime
/// stop, once a pair's `s_wi · s_aj` falls below
/// [`TermBudget::scale_floor`] × the leading product (relative rule;
/// the leading pair always runs). The rank-1 zero-point terms and the
/// activation-side sparse path follow the same axis caps; the exact
/// `A_sa`/`W_sa` sparse corrections stay exact (they are O(nnz), not
/// part of the grid, and keeping them budget-independent means a larger
/// budget only ever *adds* grid terms).
pub fn xint_linear_forward_pre_budgeted(
    a_exp: &SeriesExpansion,
    x: &Tensor,
    w: &ExpandedWeight,
    budget: &TermBudget,
) -> (Tensor, usize) {
    let (batch, in_dim) = (x.dims()[0], x.dims()[1]);
    let out_dim = w.out_dim;
    let k = w.exp.planes.len();
    let t = a_exp.planes.len();
    let (w_cap, a_cap) = budget.clamp_to(k, t);
    let mut y = Tensor::zeros(&[batch, out_dim]);
    let yd = y.data_mut();

    // tentpole: pack the activation planes to i8 once per layer call —
    // reused by every weight term of the grid below, and the row-sum
    // metadata feeds the rank-1 bias_w path further down. A plane
    // outside the i8 envelope stays `None` (scalar path).
    let a_packed: Vec<Option<Arc<PackedPlane>>> =
        a_exp.planes.iter().take(a_cap).map(|p| PackedPlane::pack(p).map(Arc::new)).collect();

    // --- INT × INT terms (the k·t low-bit GEMMs of Figure 2's red grid).
    // Resolve the (i, j) execution list first — membership and order
    // are exactly the scalar decision logic — then run it through the
    // packed SIMD/row-parallel kernel (or the scalar reference kernel
    // when a plane doesn't pack); both are bit-identical.
    let pairs: Vec<(usize, usize)> = if budget.covers(k, t) {
        let mut v = Vec::with_capacity(k * t);
        for i in 0..k {
            for j in 0..t {
                if a_exp.scales[j][0] != 0.0 {
                    v.push((i, j));
                }
            }
        }
        v
    } else {
        // largest-contribution-first: order the capped grid by the scale
        // product (max over weight channels), so any executed prefix is
        // the best approximation available at that GEMM count
        let mut scored: Vec<(usize, usize, f32)> = Vec::with_capacity(w_cap * a_cap);
        for i in 0..w_cap {
            let s_wi = w.exp.scales[i].iter().fold(0.0f32, |m, &v| m.max(v));
            for j in 0..a_cap {
                scored.push((i, j, s_wi * a_exp.scales[j][0]));
            }
        }
        // descending product (total_cmp: a NaN product must not scramble
        // the largest-first prefix); tie-break on (i+j, i) so
        // equal-scale diagonals execute in a deterministic order
        scored.sort_by(|a, b| {
            b.2.total_cmp(&a.2).then_with(|| (a.0 + a.1, a.0).cmp(&(b.0 + b.1, b.0)))
        });
        let grid_cap = budget.grid_terms.unwrap_or(usize::MAX);
        // §5.3 in-grid anytime stop: the sorted order makes the scale
        // floor a prefix rule — the first pair whose product falls
        // below the plan-carried *relative* threshold (floor × the
        // layer's leading product, scale-invariant like the pool-prefix
        // anytime stop) ends the grid; every later pair's contribution
        // is geometrically smaller still. The leading pair always
        // executes: a zero-pair forward would be garbage, not a coarser
        // approximation (the ≥ 1 floor of the budget contract).
        let leading = scored.first().map(|p| p.2).unwrap_or(0.0);
        let threshold = budget.scale_floor * leading;
        let mut sel = Vec::new();
        for &(i, j, p) in scored.iter().filter(|p| p.2 != 0.0).take(grid_cap) {
            if !sel.is_empty() && p < threshold {
                break;
            }
            sel.push((i, j));
        }
        sel
    };
    let executed = pairs.len();
    run_int_grid(&pairs, a_exp, &a_packed, w, yd);

    // --- activation zero-point × INT weight planes: bias_a · rowsum(W̃_i)
    let bias_a = a_exp.bias[0];
    if bias_a != 0.0 {
        for (i, rs) in w.plane_row_sums.iter().take(w_cap).enumerate() {
            let pc = w.exp.scales[i].len() > 1;
            for o in 0..out_dim {
                let s_wi = if pc { w.exp.scales[i][o] } else { w.exp.scales[i][0] };
                let add = bias_a * s_wi * rs[o] as f32;
                for b in 0..batch {
                    yd[b * out_dim + o] += add;
                }
            }
        }
        // activation zero-point × weight sparse part
        if let Some(sd) = &w.sparse_dense {
            for o in 0..out_dim {
                let add: f32 = bias_a * sd.row(o).iter().sum::<f32>();
                for b in 0..batch {
                    yd[b * out_dim + o] += add;
                }
            }
        }
        // activation zero-point × weight zero-point handled below via
        // the bias_w term — keep the decomposition exact.
    }

    // --- weight zero-point (asymmetric weights) × reconstructed activation:
    // bias_w[o] · Σ_k recon(A)[b,k]. The row sum of recon(A) is assembled
    // from cheap precomputable pieces — bias_a·in_dim, per-plane row sums,
    // and the sparse row sums — never from a dense reconstruction.
    if w.exp.bias.iter().any(|&b| b != 0.0) {
        let per_ch = w.exp.bias.len() > 1;
        let mut arow_sums = vec![bias_a * in_dim as f32; batch];
        for (j, aplane) in a_exp.planes.iter().take(a_cap).enumerate() {
            let s_aj = a_exp.scales[j][0];
            if s_aj == 0.0 {
                continue;
            }
            // satellite: the packed plane already carries exact per-row
            // sums — O(batch) reads instead of an O(batch·in_dim)
            // re-reduction per request; unpackable planes recompute
            match a_packed.get(j).and_then(|p| p.as_deref()) {
                Some(p) => {
                    for (acc, &rs) in arow_sums.iter_mut().zip(p.row_sums()) {
                        *acc += s_aj * rs as f32;
                    }
                }
                None => {
                    for (b, acc) in arow_sums.iter_mut().enumerate() {
                        let rs: i64 = aplane.data()[b * in_dim..(b + 1) * in_dim]
                            .iter()
                            .map(|&v| v as i64)
                            .sum();
                        *acc += s_aj * rs as f32;
                    }
                }
            }
        }
        for (&idx, &v) in a_exp.sparse.indices.iter().zip(&a_exp.sparse.values) {
            arow_sums[idx / in_dim] += v;
        }
        for (b, &xs) in arow_sums.iter().enumerate() {
            for o in 0..out_dim {
                let bw = if per_ch { w.exp.bias[o] } else { w.exp.bias[0] };
                if bw != 0.0 {
                    yd[b * out_dim + o] += bw * xs;
                }
            }
        }
    }

    // --- sparse A_sa × W terms and sparse W_sa × Ã terms
    // A_sa: activation saturation residual (exact): y += A_sa · Wᵀ_fp.
    // A_sa is very sparse — loop nnz against the cached dense weight
    // reconstruction (built once per ExpandedWeight, not per request).
    if a_exp.sparse.nnz() > 0 {
        let wrec = w.reconstructed();
        let per_ch = w.exp.bias.len() > 1;
        for (&idx, &v) in a_exp.sparse.indices.iter().zip(&a_exp.sparse.values) {
            let b = idx / w.in_dim;
            let kk = idx % w.in_dim;
            for o in 0..out_dim {
                let bw = if per_ch { w.exp.bias[o] } else { w.exp.bias[0] };
                // wrec includes bias_w; the bias_w × full-x term above
                // already paired bias_w with the full x (which includes
                // A_sa), so exclude it here.
                yd[b * out_dim + o] += v * (wrec.data()[o * w.in_dim + kk] - bw);
            }
        }
    }
    // W_sa × Ã terms: pair the weight's sparse residual with the expanded
    // activation (the INT grid used only the planes).
    if let Some(sd) = &w.sparse_dense {
        // a_expanded dense (without bias/sparse: those were paired above)
        let mut arec = Tensor::zeros(&[batch, in_dim]);
        for j in 0..a_cap.min(a_exp.planes.len()) {
            let s = a_exp.scales[j][0];
            if s == 0.0 {
                continue;
            }
            for (dst, &src) in arec.data_mut().iter_mut().zip(a_exp.planes[j].data()) {
                *dst += s * src as f32;
            }
        }
        let contrib = crate::tensor::matmul_a_bt(&arec, sd);
        for (dst, &src) in yd.iter_mut().zip(contrib.data()) {
            *dst += src;
        }
    }

    (y, executed)
}

/// Execute a resolved `(wi, aj)` pair list into `y`. When every plane
/// the list touches packed to i8, the whole grid runs through the
/// packed SIMD / row-parallel kernel ([`kernel::execute_grid`]);
/// otherwise the scalar reference loop runs the identical pair order.
/// Both routes are bit-identical (pinned by the kernel property tests),
/// so the choice is invisible to callers.
fn run_int_grid(
    pairs: &[(usize, usize)],
    a_exp: &SeriesExpansion,
    a_packed: &[Option<Arc<PackedPlane>>],
    w: &ExpandedWeight,
    y: &mut [f32],
) {
    if pairs.is_empty() {
        return;
    }
    let w_need = pairs.iter().map(|&(i, _)| i).max().map_or(0, |v| v + 1);
    let a_need = pairs.iter().map(|&(_, j)| j).max().map_or(0, |v| v + 1);
    let wp: Option<Vec<Arc<PackedPlane>>> = w.packed[..w_need].iter().cloned().collect();
    let ap: Option<Vec<Arc<PackedPlane>>> = a_packed[..a_need].iter().cloned().collect();
    if let (Some(wp), Some(ap)) = (wp, ap) {
        let run = GridRun::new(
            wp,
            w.scale_arcs[..w_need].to_vec(),
            ap,
            (0..a_need).map(|j| a_exp.scales[j][0]).collect(),
            pairs.to_vec(),
        );
        kernel::execute_grid(&Arc::new(run), y);
    } else {
        // a plane exceeded the i8 envelope (X = 8 saturating value):
        // the exact scalar kernel handles the whole list
        for &(i, j) in pairs {
            int_gemm_scaled_into(
                &a_exp.planes[j],
                &w.exp.planes[i],
                &w.exp.scales[i],
                a_exp.scales[j][0],
                y,
            );
        }
    }
}

/// Reference: dequantize both expansions densely and multiply in FP —
/// used by tests to pin the decomposed fast path to the definition.
pub fn xint_linear_reference(x: &Tensor, w: &ExpandedWeight, act_cfg: &ExpandConfig) -> Tensor {
    let a_exp = SeriesExpansion::expand(x, act_cfg);
    let a_rec = a_exp.reconstruct();
    let w_rec = w.exp.reconstruct();
    crate::tensor::matmul_a_bt(&a_rec, &w_rec)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Rng;
    use crate::xint::quantizer::{Clip, Symmetry};
    use crate::xint::BitSpec;

    fn close(a: &Tensor, b: &Tensor, tol: f32) {
        assert_eq!(a.dims(), b.dims());
        for (x, y) in a.data().iter().zip(b.data()) {
            assert!((x - y).abs() <= tol * (1.0 + x.abs().max(y.abs())), "{x} vs {y}");
        }
    }

    /// Every (symmetry, clip, channel_axis) combination used by the
    /// deployment policies — shared by the equivalence tests below.
    fn all_variants() -> Vec<(Symmetry, Clip, Option<usize>)> {
        let mut v = Vec::new();
        for sym in [Symmetry::Symmetric, Symmetry::Asymmetric] {
            for clip in [Clip::None, Clip::Laplace] {
                for ch_axis in [None, Some(0)] {
                    v.push((sym, clip, ch_axis));
                }
            }
        }
        v
    }

    #[test]
    fn int_gemm_matches_f32_gemm() {
        let mut rng = Rng::seed(31);
        let a = IntTensor::from_vec(&[4, 9], (0..36).map(|_| rng.below(17) as i32 - 8).collect());
        let b = IntTensor::from_vec(&[5, 9], (0..45).map(|_| rng.below(17) as i32 - 8).collect());
        let c = int_gemm_a_bt(&a, &b);
        let cf = crate::tensor::matmul_a_bt(&a.to_f32(), &b.to_f32());
        for (i, &v) in c.iter().enumerate() {
            assert_eq!(v as f32, cf.data()[i]);
        }
    }

    #[test]
    fn int_gemm_large_values_no_overflow() {
        // INT12-ish planes with long K: exercise the i64 fold path
        let k = 5000;
        let a = IntTensor::from_vec(&[1, k], vec![2047; k]);
        let b = IntTensor::from_vec(&[1, k], vec![2047; k]);
        let c = int_gemm_a_bt(&a, &b);
        assert_eq!(c[0], 2047i64 * 2047 * k as i64);
    }

    #[test]
    fn int_dot_exact_at_envelope_boundary() {
        // |v| == INT_DOT_MAX_ABS with K crossing many 256-chunks: each
        // i32 partial reaches its proven bound d²·CHUNK = 2^30 exactly
        // (runs in release CI, where overflow would wrap silently)
        let n = 256 * 64 + 17;
        let a: Vec<i32> =
            (0..n).map(|i| if i % 3 == 0 { -INT_DOT_MAX_ABS } else { INT_DOT_MAX_ABS }).collect();
        let b: Vec<i32> =
            (0..n).map(|i| if i % 5 == 0 { -INT_DOT_MAX_ABS } else { INT_DOT_MAX_ABS }).collect();
        let want: i64 = a.iter().zip(&b).map(|(&x, &y)| x as i64 * y as i64).sum();
        assert_eq!(int_dot(&a, &b), want);
    }

    /// The decomposed fast path must equal the dense dequantize-then-matmul
    /// reference bit-for-bit (same float ops modulo association tolerance).
    #[test]
    fn forward_matches_reference_all_variants() {
        let mut rng = Rng::seed(33);
        let x = Tensor::randn(&[3, 16], 1.0, &mut rng);
        let w_raw = Tensor::randn(&[5, 16], 0.5, &mut rng);
        for (sym, clip, ch_axis) in all_variants() {
            let wcfg = ExpandConfig {
                bits: BitSpec::int(4),
                terms: 2,
                symmetry: sym,
                clip,
                channel_axis: ch_axis,
            };
            let acfg = ExpandConfig {
                bits: BitSpec::int(4),
                terms: 3,
                symmetry: sym,
                clip,
                channel_axis: None,
            };
            let w = ExpandedWeight::new(&w_raw, &wcfg);
            let got = xint_linear_forward(&x, &w, &acfg);
            let want = xint_linear_reference(&x, &w, &acfg);
            close(&got, &want, 2e-4);
        }
    }

    /// A full budget must reproduce the legacy forward *bit-for-bit* on
    /// every quantizer variant: the full-grid path is shared code, so a
    /// budgeted Exact tier serves exactly what the seed stack served.
    #[test]
    fn full_budget_is_bit_identical_to_legacy_all_variants() {
        let mut rng = Rng::seed(34);
        let x = Tensor::randn(&[3, 16], 1.0, &mut rng);
        let w_raw = Tensor::randn(&[5, 16], 0.5, &mut rng);
        for (sym, clip, ch_axis) in all_variants() {
            let wcfg = ExpandConfig {
                bits: BitSpec::int(4),
                terms: 2,
                symmetry: sym,
                clip,
                channel_axis: ch_axis,
            };
            let acfg = ExpandConfig {
                bits: BitSpec::int(4),
                terms: 3,
                symmetry: sym,
                clip,
                channel_axis: None,
            };
            let w = ExpandedWeight::new(&w_raw, &wcfg);
            let legacy = xint_linear_forward(&x, &w, &acfg);
            let (budgeted, executed) =
                xint_linear_forward_budgeted(&x, &w, &acfg, &TermBudget::full());
            assert_eq!(legacy.data(), budgeted.data(), "sym {sym:?} clip {clip:?} ax {ch_axis:?}");
            assert!(executed <= 2 * 3, "executed {executed} of a 2×3 grid");
        }
    }

    /// Axis caps equal re-expanding at the capped term counts: truncating
    /// the activation axis to `a` is the same computation as a legacy
    /// forward whose act config has `a` terms (closed-form planes are
    /// prefix-stable).
    #[test]
    fn axis_cap_matches_shorter_expansion_bit_for_bit() {
        let mut rng = Rng::seed(38);
        let x = Tensor::randn(&[4, 24], 1.0, &mut rng);
        let w_raw = Tensor::randn(&[6, 24], 0.4, &mut rng);
        let wcfg = ExpandConfig::weights(BitSpec::int(4), 2);
        let w = ExpandedWeight::new(&w_raw, &wcfg);
        for a in 1..=4usize {
            let acfg4 = ExpandConfig::activations(BitSpec::int(4), 4);
            let (budgeted, executed) =
                xint_linear_forward_budgeted(&x, &w, &acfg4, &TermBudget::new(usize::MAX, a));
            let short = xint_linear_forward(&x, &w, &ExpandConfig::activations(BitSpec::int(4), a));
            assert_eq!(budgeted.data(), short.data(), "a_cap {a}");
            // zero-scale activation planes may be skipped, never added
            assert!(executed <= 2 * a, "a_cap {a}: executed {executed}");
        }
    }

    /// Error against the FP product is monotonically non-increasing as
    /// the budget grows, along both axes and along the sorted grid
    /// prefix (up to f32 association noise) — the contract tier budgets
    /// rely on.
    #[test]
    fn property_budget_error_monotone() {
        use crate::util::prop::{forall, no_shrink, PropConfig};
        forall(
            PropConfig { cases: 25, seed: 0xB1D6E7, max_shrink: 0 },
            |r| {
                let batch = 1 + r.below(4);
                let in_dim = 4 + r.below(24);
                let out_dim = 1 + r.below(8);
                let bits = [3u32, 4, 8][r.below(3)];
                let mut rng = r.fork(5);
                let x = Tensor::randn(&[batch, in_dim], 1.0, &mut rng);
                let w = Tensor::randn(&[out_dim, in_dim], 0.5, &mut rng);
                (x, w, bits)
            },
            no_shrink,
            |(x, w_raw, bits)| {
                let (k, t) = (2usize, 4usize);
                let wcfg = ExpandConfig::weights(BitSpec::int(*bits), k);
                let acfg = ExpandConfig::activations(BitSpec::int(*bits), t);
                let w = ExpandedWeight::new(w_raw, &wcfg);
                let fp = crate::tensor::matmul_a_bt(x, w_raw);
                let err = |budget: &TermBudget| {
                    let (y, _) = xint_linear_forward_budgeted(x, &w, &acfg, budget);
                    fp.sub(&y).max_abs()
                };
                let slack = 1e-5 * (1.0 + fp.max_abs());
                // growing either axis can only help
                let mut prev = f32::INFINITY;
                for a in 1..=t {
                    let e = err(&TermBudget::new(k, a));
                    if e > prev + slack {
                        return Err(format!("a axis: err({a}) {e} > {prev}"));
                    }
                    prev = e;
                }
                let mut prev = f32::INFINITY;
                for wc in 1..=k {
                    let e = err(&TermBudget::new(wc, t));
                    if e > prev + slack {
                        return Err(format!("w axis: err({wc}) {e} > {prev}"));
                    }
                    prev = e;
                }
                Ok(())
            },
        );
    }

    /// The sorted grid prefix under a grid cap tracks the FP product
    /// better and better as the cap grows, and the executed count obeys
    /// the cap.
    #[test]
    fn grid_cap_prefix_improves_with_budget() {
        let mut rng = Rng::seed(39);
        let x = Tensor::randn(&[4, 32], 1.0, &mut rng);
        let w_raw = Tensor::randn(&[8, 32], 0.3, &mut rng);
        let (k, t) = (2usize, 4usize);
        let w = ExpandedWeight::new(&w_raw, &ExpandConfig::weights(BitSpec::int(4), k));
        let acfg = ExpandConfig::activations(BitSpec::int(4), t);
        let fp = crate::tensor::matmul_a_bt(&x, &w_raw);
        let mut errs = Vec::new();
        for g in 1..=k * t {
            let (y, executed) = xint_linear_forward_budgeted(
                &x,
                &w,
                &acfg,
                &TermBudget::new(k, t).with_grid_terms(g),
            );
            assert!(executed <= g, "grid cap {g}: executed {executed}");
            errs.push(fp.sub(&y).max_abs());
        }
        // the full sorted grid must match the natural-order error scale
        // and the 1-GEMM prefix must be much worse than the full grid
        assert!(errs[k * t - 1] < errs[0] / 4.0, "no improvement: {errs:?}");
    }

    /// The §5.3 in-grid stop is exactly a prefix rule: a scale floor
    /// executes the same sorted prefix as the equivalent grid cap, bit
    /// for bit, and a floor above every product still runs one pair of
    /// nothing — the loop just ends at the first sub-floor product.
    #[test]
    fn scale_floor_stops_grid_at_the_sorted_prefix() {
        let mut rng = Rng::seed(41);
        let x = Tensor::randn(&[4, 32], 1.0, &mut rng);
        let w_raw = Tensor::randn(&[8, 32], 0.3, &mut rng);
        let (k, t) = (2usize, 4usize);
        let w = ExpandedWeight::new(&w_raw, &ExpandConfig::weights(BitSpec::int(4), k));
        let acfg = ExpandConfig::activations(BitSpec::int(4), t);
        // recompute the sorted products the budgeted forward uses
        let a_exp = SeriesExpansion::expand(&x, &acfg);
        let mut products: Vec<f32> = Vec::new();
        for i in 0..k {
            let s_wi = w.exp.scales[i].iter().fold(0.0f32, |m, &v| m.max(v));
            for j in 0..t {
                products.push(s_wi * a_exp.scales[j][0]);
            }
        }
        products.sort_by(|a, b| b.partial_cmp(a).unwrap());
        products.retain(|&p| p != 0.0);
        // pick a *relative* floor strictly between two adjacent
        // products (the stop threshold is floor × the leading product)
        let mid = (products[2] + products[3]) / 2.0;
        let floor = mid / products[0];
        let expect = products.iter().filter(|&&p| p >= floor * products[0]).count();
        assert!(expect >= 1 && expect < products.len());
        let budget = TermBudget::new(k, t).with_scale_floor(floor);
        let (y_floor, e_floor) = xint_linear_forward_budgeted(&x, &w, &acfg, &budget);
        assert_eq!(e_floor, expect, "floor {floor} should keep {expect} pairs");
        // same prefix via an explicit grid cap → bit-identical output
        let capped = TermBudget::new(k, t).with_grid_terms(expect);
        let (y_cap, e_cap) = xint_linear_forward_budgeted(&x, &w, &acfg, &capped);
        assert_eq!(e_cap, expect);
        assert_eq!(y_floor.data(), y_cap.data());
        // the leading pair always executes, even under an impossible
        // floor — a zero-pair forward would violate the ≥ 1 contract
        let impossible = TermBudget::new(k, t).with_scale_floor(2.0);
        let (_, e_one) = xint_linear_forward_budgeted(&x, &w, &acfg, &impossible);
        assert_eq!(e_one, 1, "the leading pair is unconditional");
        // a zero floor with covering axis caps stays on the legacy path
        let (y_full, _) = xint_linear_forward_budgeted(&x, &w, &acfg, &TermBudget::full());
        let legacy = xint_linear_forward(&x, &w, &acfg);
        assert_eq!(y_full.data(), legacy.data());
    }

    #[test]
    fn forward_converges_to_fp_with_terms() {
        let mut rng = Rng::seed(35);
        let x = Tensor::randn(&[4, 32], 1.0, &mut rng);
        let w_raw = Tensor::randn(&[8, 32], 0.3, &mut rng);
        let fp = crate::tensor::matmul_a_bt(&x, &w_raw);
        let mut errs = Vec::new();
        for terms in 1..=4 {
            let wcfg = ExpandConfig::weights(BitSpec::int(4), 2);
            let acfg = ExpandConfig::symmetric(BitSpec::int(4), terms);
            let w = ExpandedWeight::new(&w_raw, &wcfg);
            let y = xint_linear_forward(&x, &w, &acfg);
            errs.push(fp.sub(&y).max_abs());
        }
        assert!(errs[3] < errs[0] / 10.0, "no convergence: {errs:?}");
    }

    #[test]
    fn w8a8_single_term_is_tight() {
        let mut rng = Rng::seed(36);
        let x = Tensor::randn(&[2, 64], 1.0, &mut rng);
        let w_raw = Tensor::randn(&[4, 64], 0.2, &mut rng);
        let fp = crate::tensor::matmul_a_bt(&x, &w_raw);
        let w = ExpandedWeight::new(&w_raw, &ExpandConfig::symmetric(BitSpec::int(8), 1));
        let y = xint_linear_forward(&x, &w, &ExpandConfig::symmetric(BitSpec::int(8), 1));
        let rel = fp.sub(&y).norm() / fp.norm();
        assert!(rel < 0.02, "W8A8 relative error {rel}");
    }

    #[test]
    fn row_sums_precompute_is_consistent() {
        let mut rng = Rng::seed(37);
        let w_raw = Tensor::randn(&[6, 10], 1.0, &mut rng);
        let w = ExpandedWeight::new(&w_raw, &ExpandConfig::symmetric(BitSpec::int(4), 2));
        for (i, plane) in w.exp.planes.iter().enumerate() {
            for o in 0..6 {
                let s: i64 = plane.data()[o * 10..(o + 1) * 10].iter().map(|&v| v as i64).sum();
                assert_eq!(s, w.plane_row_sums[i][o]);
            }
        }
    }

    #[test]
    fn cached_reconstruction_matches_expansion() {
        let mut rng = Rng::seed(40);
        let w_raw = Tensor::randn(&[6, 10], 1.0, &mut rng);
        let w = ExpandedWeight::new(&w_raw, &ExpandConfig::activations(BitSpec::int(4), 2));
        assert_eq!(w.reconstructed().data(), w.exp.reconstruct().data());
        // second call returns the same cached tensor
        let p1 = w.reconstructed() as *const Tensor;
        let p2 = w.reconstructed() as *const Tensor;
        assert_eq!(p1, p2);
    }
}
