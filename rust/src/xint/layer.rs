//! Eq. 4 — single-layer low-bit expansion: expanded linear and conv
//! layers plus the paper's deployment policy (§5.1): per-channel weights,
//! Laplace-clipped activations, 8-bit first/last layer, and the §4
//! weight-term upper bound (`scale_k · 2^X < 10^{-2}` ⇒ k ≈ 2–3).

use super::budget::TermBudget;
use super::expansion::ExpandConfig;
use super::gemm::{xint_linear_forward, xint_linear_forward_budgeted, ExpandedWeight};
use super::quantizer::{Clip, Symmetry};
use super::BitSpec;
use crate::tensor::{conv2d, im2col, Conv2dSpec, Tensor};

/// Per-layer quantization policy resolved by the model quantizer.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct LayerPolicy {
    pub w_bits: BitSpec,
    pub a_bits: BitSpec,
    /// INT terms for the weight expansion (§4 bound caps this at 3)
    pub w_terms: usize,
    /// INT terms for the activation expansion
    pub a_terms: usize,
    pub clip: Clip,
    pub symmetry: Symmetry,
}

impl LayerPolicy {
    /// The paper's default: WxAy with Laplace clip, k=2 weight terms,
    /// t=4 activation terms.
    pub fn new(w_bits: u32, a_bits: u32) -> Self {
        LayerPolicy {
            w_bits: BitSpec::int(w_bits),
            a_bits: BitSpec::int(a_bits),
            w_terms: 2,
            a_terms: 4,
            clip: Clip::Laplace,
            symmetry: Symmetry::Symmetric,
        }
    }

    /// 8-bit single-term policy for first/last layers (§5.1).
    pub fn eight_bit() -> Self {
        LayerPolicy {
            w_bits: BitSpec::int(8),
            a_bits: BitSpec::int(8),
            w_terms: 1,
            a_terms: 1,
            clip: Clip::None,
            symmetry: Symmetry::Symmetric,
        }
    }

    pub fn with_terms(mut self, w_terms: usize, a_terms: usize) -> Self {
        self.w_terms = w_terms;
        self.a_terms = a_terms;
        self
    }

    pub fn with_clip(mut self, clip: Clip) -> Self {
        self.clip = clip;
        self
    }

    pub fn weight_config(&self) -> ExpandConfig {
        ExpandConfig {
            bits: self.w_bits,
            terms: self.w_terms,
            symmetry: self.symmetry,
            clip: self.clip,
            channel_axis: Some(0),
        }
    }

    pub fn act_config(&self) -> ExpandConfig {
        ExpandConfig {
            bits: self.a_bits,
            terms: self.a_terms,
            symmetry: self.symmetry,
            clip: self.clip,
            channel_axis: None,
        }
    }

    /// §5.1 exemption: 8-bit (first/last) layers are pinned exact — no
    /// request budget or plan entry may truncate them, and the
    /// [`BudgetPlanner`](super::planner::BudgetPlanner) does not charge
    /// them against the grid ceiling.
    pub fn is_exempt(&self) -> bool {
        self.w_bits.bits >= 8 && self.a_bits.bits >= 8
    }

    /// Resolve this layer's [`TermBudget`] — the per-layer entry of a
    /// [`BudgetPlan`](super::budget::BudgetPlan), or a request-level
    /// scalar — against the policy: §5.1-exempt layers stay exact under
    /// any budget, every other layer takes the entry as-is (its caps
    /// clamp to the layer's own term counts downstream).
    pub fn resolve_budget(&self, budget: &TermBudget) -> TermBudget {
        if self.is_exempt() {
            TermBudget::full()
        } else {
            *budget
        }
    }
}

/// §4 "Weight Expansion Upper Bound": grow k until the *total differential*
/// criterion `scale_k · 2^X < threshold` holds (default 1e-2), capped at
/// `max_terms`. Returns the number of weight terms to use.
pub fn weight_term_bound(w: &Tensor, bits: BitSpec, threshold: f32, max_terms: usize) -> usize {
    let half = bits.half() as f32;
    let levels = bits.levels() as f32;
    let scale1 = w.max_abs() / half;
    let mut k = 1;
    let mut s = scale1;
    while s * levels >= threshold && k < max_terms {
        s /= levels;
        k += 1;
    }
    k
}

/// An expanded (quantized) linear layer `y = x Wᵀ + b`.
#[derive(Clone, Debug)]
pub struct XintLinear {
    pub weight: ExpandedWeight,
    pub bias: Option<Tensor>,
    pub policy: LayerPolicy,
}

impl XintLinear {
    pub fn from_fp(w: &Tensor, bias: Option<&Tensor>, policy: LayerPolicy) -> Self {
        XintLinear {
            weight: ExpandedWeight::new(w, &policy.weight_config()),
            bias: bias.cloned(),
            policy,
        }
    }

    pub fn forward(&self, x: &Tensor) -> Tensor {
        let y = xint_linear_forward(x, &self.weight, &self.policy.act_config());
        match &self.bias {
            Some(b) => y.add_row_bias(b),
            None => y,
        }
    }

    /// Budgeted forward: truncate the Eq. 3 grid per the resolved
    /// budget. Returns the output and the INT GEMM terms executed; a
    /// full budget is bit-identical to [`XintLinear::forward`].
    pub fn forward_with(&self, x: &Tensor, budget: &TermBudget) -> (Tensor, usize) {
        let b = self.policy.resolve_budget(budget);
        let (y, executed) =
            xint_linear_forward_budgeted(x, &self.weight, &self.policy.act_config(), &b);
        let y = match &self.bias {
            Some(bias) => y.add_row_bias(bias),
            None => y,
        };
        (y, executed)
    }

    /// Storage of the quantized layer in bytes (Table 3 accounting).
    pub fn storage_bytes(&self) -> usize {
        self.weight.exp.storage_bytes() + self.bias.as_ref().map_or(0, |b| b.numel() * 4)
    }
}

/// An expanded conv layer: im2col + [`XintLinear`]-style expanded GEMM,
/// so conv inherits Eq. 3 unchanged (grouped convs fall back to FP weights
/// reconstructed once — their GEMMs are tiny).
#[derive(Clone, Debug)]
pub struct XintConv2d {
    pub spec: Conv2dSpec,
    /// weight flattened to (out_ch, in_ch/g · kh · kw), expanded
    pub weight: ExpandedWeight,
    pub bias: Option<Tensor>,
    pub policy: LayerPolicy,
    /// dense FP weight for grouped convs (g > 1) where the per-group GEMM
    /// shape doesn't match the flattened expansion
    fp_weight: Option<Tensor>,
}

impl XintConv2d {
    pub fn from_fp(
        w: &Tensor,
        bias: Option<&Tensor>,
        spec: Conv2dSpec,
        policy: LayerPolicy,
    ) -> Self {
        assert_eq!(w.dims()[0], spec.out_ch);
        let kelem = (spec.in_ch / spec.groups) * spec.kh * spec.kw;
        let flat = w.reshape(&[spec.out_ch, kelem]);
        let fp_weight = if spec.groups > 1 {
            // reconstruct the quantized weight once; run grouped conv in FP.
            // The quantization ERROR is still faithful (weights go through
            // the expansion); only the multiplication is not INT-decomposed.
            let exp = super::expansion::SeriesExpansion::expand(&flat, &policy.weight_config());
            Some(exp.reconstruct().reshaped(w.dims()))
        } else {
            None
        };
        XintConv2d {
            spec,
            weight: ExpandedWeight::new(&flat, &policy.weight_config()),
            bias: bias.cloned(),
            policy,
            fp_weight,
        }
    }

    pub fn forward(&self, x: &Tensor) -> Tensor {
        self.forward_with(x, &TermBudget::full()).0
    }

    /// Budgeted forward: the im2col GEMM inherits the resolved budget
    /// per image (grouped convs keep their FP fallback — their GEMMs
    /// are tiny and not INT-decomposed, so there is no grid to cap).
    /// Returns the output and the INT GEMM terms executed.
    pub fn forward_with(&self, x: &Tensor, budget: &TermBudget) -> (Tensor, usize) {
        let (n, c, h, w) = (x.dims()[0], x.dims()[1], x.dims()[2], x.dims()[3]);
        assert_eq!(c, self.spec.in_ch);
        let (oh, ow) = self.spec.out_hw(h, w);
        if let Some(fpw) = &self.fp_weight {
            // grouped path: quantize activations per-tensor, conv in FP
            let a_exp =
                super::expansion::SeriesExpansion::expand(x, &self.policy.act_config());
            let xq = a_exp.reconstruct();
            return (conv2d(&xq, fpw, self.bias.as_ref(), &self.spec), 0);
        }
        let b = self.policy.resolve_budget(budget);
        let mut executed = 0usize;
        // im2col batch → one expanded GEMM per image
        let mut out = Tensor::zeros(&[n, self.spec.out_ch, oh, ow]);
        let chw = c * h * w;
        for ni in 0..n {
            let img = &x.data()[ni * chw..(ni + 1) * chw];
            let cols = im2col(img, c, h, w, &self.spec); // (kelem, oh*ow)
            let cols_t = cols.transpose2(); // (oh*ow, kelem) = "batch" rows
            let (y, e) = xint_linear_forward_budgeted(
                &cols_t,
                &self.weight,
                &self.policy.act_config(),
                &b,
            );
            executed += e;
            // y: (oh*ow, out_ch) → write transposed into NCHW
            for oc in 0..self.spec.out_ch {
                let base = (ni * self.spec.out_ch + oc) * oh * ow;
                for p in 0..oh * ow {
                    out.data_mut()[base + p] = y.data()[p * self.spec.out_ch + oc];
                }
            }
        }
        if let Some(b) = &self.bias {
            let od = out.data_mut();
            for ni in 0..n {
                for oc in 0..self.spec.out_ch {
                    let bv = b.data()[oc];
                    let base = (ni * self.spec.out_ch + oc) * oh * ow;
                    for v in &mut od[base..base + oh * ow] {
                        *v += bv;
                    }
                }
            }
        }
        (out, executed)
    }

    pub fn storage_bytes(&self) -> usize {
        self.weight.exp.storage_bytes() + self.bias.as_ref().map_or(0, |b| b.numel() * 4)
    }

    /// True for grouped convs, which run the FP-fallback path: they
    /// have no INT grid to truncate, so the budget planner treats them
    /// as exempt (allocating grid terms to them would waste ceiling).
    pub fn uses_fp_fallback(&self) -> bool {
        self.fp_weight.is_some()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Rng;

    #[test]
    fn weight_bound_small_for_trained_scales() {
        // typical trained-layer weight max ~0.5 → INT4: s1·16 = 0.5·2 = 1.0,
        // s2·16 = 1/16 … needs k≈3 to get under 1e-2
        let w = Tensor::from_vec(&[1, 2], vec![0.5, -0.5]);
        let k = weight_term_bound(&w, BitSpec::int(4), 1e-2, 5);
        assert_eq!(k, 3);
        // INT8 reaches the bound faster
        let k8 = weight_term_bound(&w, BitSpec::int(8), 1e-2, 5);
        assert_eq!(k8, 2);
    }

    #[test]
    fn linear_layer_close_to_fp() {
        let mut rng = Rng::seed(41);
        let w = Tensor::randn(&[8, 16], 0.3, &mut rng);
        let b = Tensor::randn(&[8], 0.1, &mut rng);
        let x = Tensor::randn(&[4, 16], 1.0, &mut rng);
        let fp = crate::tensor::matmul_a_bt(&x, &w).add_row_bias(&b);
        let layer = XintLinear::from_fp(&w, Some(&b), LayerPolicy::new(4, 4));
        let y = layer.forward(&x);
        let rel = fp.sub(&y).norm() / fp.norm();
        assert!(rel < 0.02, "W4A4 k=2 t=4 rel err {rel}");
    }

    #[test]
    fn eight_bit_policy_tighter_than_w2a2() {
        let mut rng = Rng::seed(43);
        let w = Tensor::randn(&[8, 16], 0.3, &mut rng);
        let x = Tensor::randn(&[4, 16], 1.0, &mut rng);
        let fp = crate::tensor::matmul_a_bt(&x, &w);
        let err = |p: LayerPolicy| {
            let l = XintLinear::from_fp(&w, None, p);
            fp.sub(&l.forward(&x)).norm() / fp.norm()
        };
        let e8 = err(LayerPolicy::eight_bit());
        let e2 = err(LayerPolicy::new(2, 2).with_terms(1, 1));
        assert!(e8 < e2 / 4.0, "8bit {e8} vs 2bit {e2}");
    }

    #[test]
    fn conv_layer_close_to_fp() {
        let mut rng = Rng::seed(45);
        let spec = Conv2dSpec::new(3, 6, 3, 1, 1);
        let w = Tensor::randn(&[6, 3, 3, 3], 0.2, &mut rng);
        let b = Tensor::randn(&[6], 0.05, &mut rng);
        let x = Tensor::randn(&[2, 3, 8, 8], 1.0, &mut rng);
        let fp = conv2d(&x, &w, Some(&b), &spec);
        let q = XintConv2d::from_fp(&w, Some(&b), spec, LayerPolicy::new(4, 4));
        let y = q.forward(&x);
        assert_eq!(y.dims(), fp.dims());
        let rel = fp.sub(&y).norm() / fp.norm();
        assert!(rel < 0.03, "conv W4A4 rel err {rel}");
    }

    #[test]
    fn depthwise_conv_grouped_path() {
        let mut rng = Rng::seed(47);
        let spec = Conv2dSpec::depthwise(4, 3, 1, 1);
        let w = Tensor::randn(&[4, 1, 3, 3], 0.3, &mut rng);
        let x = Tensor::randn(&[1, 4, 6, 6], 1.0, &mut rng);
        let fp = conv2d(&x, &w, None, &spec);
        let q = XintConv2d::from_fp(&w, None, spec, LayerPolicy::new(4, 4));
        let y = q.forward(&x);
        let rel = fp.sub(&y).norm() / fp.norm();
        assert!(rel < 0.05, "depthwise W4A4 rel err {rel}");
    }

    #[test]
    fn budgeted_linear_full_identical_low_budget_fewer_gemms() {
        let mut rng = Rng::seed(49);
        let w = Tensor::randn(&[8, 16], 0.3, &mut rng);
        let b = Tensor::randn(&[8], 0.1, &mut rng);
        let x = Tensor::randn(&[4, 16], 1.0, &mut rng);
        let layer = XintLinear::from_fp(&w, Some(&b), LayerPolicy::new(4, 4)); // k=2, t=4
        let legacy = layer.forward(&x);
        let (full, e_full) = layer.forward_with(&x, &TermBudget::full());
        assert_eq!(legacy.data(), full.data(), "full budget must be bit-identical");
        let (cheap, e_cheap) = layer.forward_with(&x, &TermBudget::new(1, 1));
        assert!(e_cheap < e_full, "{e_cheap} !< {e_full}");
        assert!(e_cheap <= 1);
        // the 1×1 grid is still a coarse but finite approximation
        let rel = legacy.sub(&cheap).norm() / legacy.norm();
        assert!(rel.is_finite() && rel < 1.0, "budgeted rel err {rel}");
    }

    #[test]
    fn eight_bit_policy_is_exempt_from_budgets() {
        let mut rng = Rng::seed(50);
        let w = Tensor::randn(&[8, 16], 0.3, &mut rng);
        let x = Tensor::randn(&[4, 16], 1.0, &mut rng);
        // 8-bit multi-term layer: a minimal budget must not truncate it
        let p = LayerPolicy::eight_bit().with_terms(2, 2);
        assert_eq!(p.resolve_budget(&TermBudget::new(1, 1)), TermBudget::full());
        let l = XintLinear::from_fp(&w, None, p);
        let (y_min, e_min) = l.forward_with(&x, &TermBudget::new(1, 1));
        let (y_full, e_full) = l.forward_with(&x, &TermBudget::full());
        assert_eq!(y_min.data(), y_full.data());
        assert_eq!(e_min, e_full);
        // a sub-8-bit layer with the same terms IS truncated
        let l4 = XintLinear::from_fp(&w, None, LayerPolicy::new(4, 4).with_terms(2, 2));
        let (_, e4) = l4.forward_with(&x, &TermBudget::new(1, 1));
        assert!(e4 <= 1, "low-bit layer must honor the budget: {e4}");
    }

    #[test]
    fn budgeted_conv_full_identical_low_budget_fewer_gemms() {
        let mut rng = Rng::seed(51);
        let spec = Conv2dSpec::new(3, 6, 3, 1, 1);
        let w = Tensor::randn(&[6, 3, 3, 3], 0.2, &mut rng);
        let x = Tensor::randn(&[2, 3, 8, 8], 1.0, &mut rng);
        let q = XintConv2d::from_fp(&w, None, spec, LayerPolicy::new(4, 4));
        let legacy = q.forward(&x);
        let (full, e_full) = q.forward_with(&x, &TermBudget::full());
        assert_eq!(legacy.data(), full.data());
        let (cheap, e_cheap) = q.forward_with(&x, &TermBudget::new(1, 1));
        assert!(e_cheap < e_full, "{e_cheap} !< {e_full}");
        assert_eq!(cheap.dims(), legacy.dims());
    }

    #[test]
    fn storage_shrinks_with_bits() {
        let mut rng = Rng::seed(48);
        let w = Tensor::randn(&[32, 64], 0.3, &mut rng);
        let l4 = XintLinear::from_fp(&w, None, LayerPolicy::new(4, 4).with_terms(1, 1));
        let l2 = XintLinear::from_fp(&w, None, LayerPolicy::new(2, 2).with_terms(1, 1));
        assert!(l2.storage_bytes() < l4.storage_bytes());
    }
}
