//! Single-step integer quantization — the "computational kernel for
//! constructing basis functions" (§3.1).
//!
//! Variants follow the paper's taxonomy: symmetric vs asymmetric zero
//! point, saturating (clipped range, residual absorbed by the sparse
//! `M_sa` tensor) vs non-saturating (full min/max range). The saturating
//! clip threshold is chosen analytically for a Laplace activation model,
//! "the expected quantization noise in the Laplace distribution as the
//! clipping function" (§5.1) — i.e. ACIQ-style MSE-optimal clipping.

use super::BitSpec;

/// Zero-point handling.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Symmetry {
    /// zero point at 0; range `[-c, c]`
    Symmetric,
    /// zero point at the range midpoint (the paper's `bias · M_nsy` term)
    Asymmetric,
}

/// Range / clipping strategy.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Clip {
    /// non-saturating: full observed range, `M_sa = 0`
    None,
    /// saturating at the Laplace-MSE-optimal threshold (ACIQ-style)
    Laplace,
    /// saturating at a fixed absolute threshold
    Fixed(f32),
    /// saturating at the p-th percentile of |x - μ| (p in [0,100])
    Percentile(f32),
}

/// Channel-range statistics produced by [`channel_range`].
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Range {
    /// zero point (0 for symmetric)
    pub bias: f32,
    /// half-width of the quantized interval `[bias - c, bias + c]`
    pub half_width: f32,
}

/// Expected quantization MSE of X-bit uniform quantization of Laplace(b)
/// clipped at ±alpha: clipping term `2 b² e^{-α/b}` plus rounding term
/// `α² / (3 · 4^X)` (step Δ = 2α/2^X, noise Δ²/12).
pub fn laplace_mse(alpha: f32, b: f32, bits: u32) -> f32 {
    let clip_term = 2.0 * b * b * (-alpha / b).exp();
    let steps = (1u64 << bits) as f32;
    let round_term = alpha * alpha / (3.0 * steps * steps);
    clip_term + round_term
}

/// MSE-optimal clip threshold for Laplace(b) at the given bit-width
/// (golden-section search on the unimodal objective).
pub fn optimal_laplace_clip(b: f32, bits: u32) -> f32 {
    if b <= 0.0 {
        return 0.0;
    }
    let (mut lo, mut hi) = (0.5 * b, 25.0 * b);
    let phi = 0.618_034f32;
    for _ in 0..60 {
        let m1 = hi - phi * (hi - lo);
        let m2 = lo + phi * (hi - lo);
        if laplace_mse(m1, b, bits) < laplace_mse(m2, b, bits) {
            hi = m2;
        } else {
            lo = m1;
        }
    }
    0.5 * (lo + hi)
}

/// Compute the quantization range of one channel of data.
pub fn channel_range(xs: &[f32], sym: Symmetry, clip: Clip, bits: u32) -> Range {
    if xs.is_empty() {
        return Range { bias: 0.0, half_width: 0.0 };
    }
    let (mut lo, mut hi) = (f32::INFINITY, f32::NEG_INFINITY);
    let mut sum = 0.0f64;
    for &v in xs {
        lo = lo.min(v);
        hi = hi.max(v);
        sum += v as f64;
    }
    let mean = (sum / xs.len() as f64) as f32;
    let bias = match sym {
        Symmetry::Symmetric => 0.0,
        // the paper's bias = (vmax - vmin)/2 + vmin — the midpoint
        Symmetry::Asymmetric => 0.5 * (hi + lo),
    };
    let full = match sym {
        Symmetry::Symmetric => lo.abs().max(hi.abs()),
        Symmetry::Asymmetric => 0.5 * (hi - lo),
    };
    let half_width = match clip {
        Clip::None => full,
        Clip::Fixed(c) => c.min(full),
        Clip::Laplace => {
            // Laplace scale estimated around the zero point actually used
            let center = match sym {
                Symmetry::Symmetric => 0.0,
                Symmetry::Asymmetric => mean,
            };
            let b = xs.iter().map(|&v| (v - center).abs()).sum::<f32>() / xs.len() as f32;
            optimal_laplace_clip(b, bits).min(full)
        }
        Clip::Percentile(p) => {
            let center = match sym {
                Symmetry::Symmetric => 0.0,
                Symmetry::Asymmetric => bias,
            };
            let mut devs: Vec<f32> = xs.iter().map(|&v| (v - center).abs()).collect();
            devs.sort_by(|a, b| a.partial_cmp(b).unwrap());
            let rank = ((p / 100.0) * (devs.len() - 1) as f32).round() as usize;
            devs[rank.min(devs.len() - 1)].min(full)
        }
    };
    Range { bias, half_width }
}

/// One-shot quantize/dequantize of a slice at `bits` with the given range
/// (round-to-nearest, saturating at the range edge). Returns the
/// dequantized values — this is the plain-PTQ primitive the baselines use.
pub fn fake_quant(xs: &[f32], r: Range, spec: BitSpec) -> Vec<f32> {
    if r.half_width <= 0.0 {
        return vec![r.bias; xs.len()];
    }
    let half = spec.half() as f32;
    let scale = r.half_width / half;
    xs.iter()
        .map(|&v| {
            let q = ((v - r.bias) / scale).round().clamp(-half, half - 1.0);
            r.bias + q * scale
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Rng;

    #[test]
    fn laplace_mse_decomposes() {
        // at alpha -> inf, only rounding noise; at alpha -> 0, only clip noise
        let b = 1.0;
        assert!((laplace_mse(50.0, b, 4) - 2500.0 / (3.0 * 256.0)).abs() < 1e-3);
        assert!((laplace_mse(1e-6, b, 4) - 2.0).abs() < 1e-3);
    }

    #[test]
    fn optimal_clip_is_stationary() {
        for &bits in &[2u32, 4, 8] {
            let b = 1.7;
            let a = optimal_laplace_clip(b, bits);
            let f0 = laplace_mse(a, b, bits);
            for d in [-0.05f32, 0.05] {
                assert!(
                    laplace_mse(a + d * b, b, bits) >= f0 - 1e-6,
                    "bits {bits}: not a minimum at {a}"
                );
            }
        }
    }

    #[test]
    fn optimal_clip_grows_with_bits() {
        // more bits -> cheaper rounding -> wider optimal range
        let b = 1.0;
        let a2 = optimal_laplace_clip(b, 2);
        let a4 = optimal_laplace_clip(b, 4);
        let a8 = optimal_laplace_clip(b, 8);
        assert!(a2 < a4 && a4 < a8, "{a2} {a4} {a8}");
    }

    #[test]
    fn symmetric_range_covers_max_abs() {
        let r = channel_range(&[-3.0, 1.0, 2.0], Symmetry::Symmetric, Clip::None, 4);
        assert_eq!(r.bias, 0.0);
        assert_eq!(r.half_width, 3.0);
    }

    #[test]
    fn asymmetric_bias_is_midpoint() {
        let r = channel_range(&[2.0, 6.0], Symmetry::Asymmetric, Clip::None, 4);
        assert_eq!(r.bias, 4.0);
        assert_eq!(r.half_width, 2.0);
    }

    #[test]
    fn laplace_clip_tighter_than_range_on_heavy_tail() {
        let mut rng = Rng::seed(17);
        let xs: Vec<f32> = (0..20_000).map(|_| rng.laplace(1.0)).collect();
        let r_none = channel_range(&xs, Symmetry::Symmetric, Clip::None, 4);
        let r_lap = channel_range(&xs, Symmetry::Symmetric, Clip::Laplace, 4);
        assert!(r_lap.half_width < r_none.half_width);
        // and the clipped quantizer must have lower empirical MSE
        let spec = BitSpec::int(4);
        let mse = |r: Range| {
            let q = fake_quant(&xs, r, spec);
            xs.iter().zip(&q).map(|(a, b)| (a - b) * (a - b)).sum::<f32>() / xs.len() as f32
        };
        assert!(mse(r_lap) < mse(r_none), "{} vs {}", mse(r_lap), mse(r_none));
    }

    #[test]
    fn percentile_clip_bounds() {
        let xs: Vec<f32> = (0..101).map(|i| i as f32).collect();
        let r = channel_range(&xs, Symmetry::Asymmetric, Clip::Percentile(90.0), 4);
        assert!(r.half_width <= 50.0);
        assert!(r.half_width >= 40.0);
    }

    #[test]
    fn fake_quant_error_bounded_by_step() {
        let mut rng = Rng::seed(99);
        let xs: Vec<f32> = (0..1000).map(|_| rng.uniform(-2.0, 2.0)).collect();
        let spec = BitSpec::int(8);
        let r = channel_range(&xs, Symmetry::Symmetric, Clip::None, 8);
        let q = fake_quant(&xs, r, spec);
        let step = r.half_width / spec.half() as f32;
        for (a, b) in xs.iter().zip(&q) {
            // one extra step of slack for the asymmetric clamp at +half-1
            assert!((a - b).abs() <= step * 1.01 + 1e-6, "{a} vs {b}");
        }
    }

    #[test]
    fn degenerate_channel_is_stable() {
        let r = channel_range(&[0.0, 0.0], Symmetry::Symmetric, Clip::Laplace, 4);
        assert_eq!(r.half_width, 0.0);
        let q = fake_quant(&[0.0, 0.0], r, BitSpec::int(4));
        assert_eq!(q, vec![0.0, 0.0]);
    }
}
