//! Theorem 1 — tensor low-bit series expansion.
//!
//! `M = M_sa + bias·M_nsy + Σ_{i=1..n} scale_i · M̃_i`, with the geometric
//! scale law `scale_i = 2^X · scale_{i+1}` and every `M̃_i` an INT(X)
//! tensor. Planes are computed with the §4 *parallel* closed form
//!
//! `M̃_k(i,j) = round(M'/s_k) − 2^X · round(M'/s_{k−1})`
//!
//! which telescopes to `Σ s_i M̃_i = s_n · round(M'/s_n)`, hence the
//! exponential convergence `‖residual‖∞ ≤ s_n/2` (Theorem 1's proof).
//! Supports per-tensor or per-channel (axis 0) ranges, matching the
//! paper's channel-by-channel quantization (§5.1).

use super::quantizer::{channel_range, Clip, Range, Symmetry};
use super::BitSpec;
use crate::tensor::{IntTensor, Tensor};

/// Sparse COO tensor holding the saturation residual `M_sa` (§3.1).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct SparseTensor {
    pub dims: Vec<usize>,
    pub indices: Vec<usize>,
    pub values: Vec<f32>,
}

impl SparseTensor {
    pub fn empty(dims: &[usize]) -> Self {
        SparseTensor { dims: dims.to_vec(), indices: Vec::new(), values: Vec::new() }
    }

    pub fn nnz(&self) -> usize {
        self.values.len()
    }

    pub fn to_dense(&self) -> Tensor {
        let mut t = Tensor::zeros(&self.dims);
        for (&i, &v) in self.indices.iter().zip(&self.values) {
            t.data_mut()[i] = v;
        }
        t
    }

    /// Add `self` into a dense accumulator.
    pub fn add_into(&self, out: &mut Tensor) {
        assert_eq!(out.dims(), &self.dims[..]);
        for (&i, &v) in self.indices.iter().zip(&self.values) {
            out.data_mut()[i] += v;
        }
    }
}

/// Configuration of a series expansion.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ExpandConfig {
    pub bits: BitSpec,
    /// number of INT terms `n`
    pub terms: usize,
    pub symmetry: Symmetry,
    pub clip: Clip,
    /// `Some(0)`: per-channel along axis 0 (weights); `None`: per-tensor
    pub channel_axis: Option<usize>,
}

impl ExpandConfig {
    /// Non-saturating symmetric per-tensor expansion — the proof's base case.
    pub fn symmetric(bits: BitSpec, terms: usize) -> Self {
        ExpandConfig { bits, terms, symmetry: Symmetry::Symmetric, clip: Clip::None, channel_axis: None }
    }

    /// The paper's weight policy: per-channel symmetric, Laplace clip.
    pub fn weights(bits: BitSpec, terms: usize) -> Self {
        ExpandConfig {
            bits,
            terms,
            symmetry: Symmetry::Symmetric,
            clip: Clip::Laplace,
            channel_axis: Some(0),
        }
    }

    /// The paper's activation policy: per-tensor asymmetric, Laplace clip.
    pub fn activations(bits: BitSpec, terms: usize) -> Self {
        ExpandConfig {
            bits,
            terms,
            symmetry: Symmetry::Asymmetric,
            clip: Clip::Laplace,
            channel_axis: None,
        }
    }

    pub fn with_clip(mut self, clip: Clip) -> Self {
        self.clip = clip;
        self
    }

    pub fn with_terms(mut self, terms: usize) -> Self {
        self.terms = terms;
        self
    }
}

/// The expansion of one tensor: `bias`, scales and INT planes per channel,
/// plus the sparse saturation residual.
#[derive(Clone, Debug)]
pub struct SeriesExpansion {
    pub config: ExpandConfig,
    pub dims: Vec<usize>,
    /// zero point per channel (len = #channels; 1 for per-tensor)
    pub bias: Vec<f32>,
    /// `scales[t][c]`: scale of term `t` for channel `c`
    pub scales: Vec<Vec<f32>>,
    /// INT(X) basis planes, each with the full tensor shape
    pub planes: Vec<IntTensor>,
    /// saturation residual `M_sa` (empty when non-saturating)
    pub sparse: SparseTensor,
}

impl SeriesExpansion {
    /// Expand `m` per Theorem 1.
    pub fn expand(m: &Tensor, cfg: &ExpandConfig) -> SeriesExpansion {
        assert!(cfg.terms >= 1, "need at least one term");
        let dims = m.dims().to_vec();
        let (nch, chlen) = match cfg.channel_axis {
            Some(0) => (dims[0], m.numel() / dims[0].max(1)),
            None => (1, m.numel()),
            Some(a) => panic!("channel_axis {a} unsupported (only 0)"),
        };
        let levels = (1i64 << cfg.bits.bits) as f32;
        let half = cfg.bits.half() as f32;

        let mut bias = vec![0.0f32; nch];
        let mut scale1 = vec![0.0f32; nch];
        let mut ranges = vec![Range { bias: 0.0, half_width: 0.0 }; nch];
        for c in 0..nch {
            let xs = &m.data()[c * chlen..(c + 1) * chlen];
            let r = channel_range(xs, cfg.symmetry, cfg.clip, cfg.bits.bits);
            bias[c] = r.bias;
            scale1[c] = r.half_width / half;
            ranges[c] = r;
        }

        // sparse saturation residual: whatever the clipped range misses
        let mut sparse = SparseTensor::empty(&dims);
        if !matches!(cfg.clip, Clip::None) {
            for c in 0..nch {
                let r = ranges[c];
                for j in 0..chlen {
                    let idx = c * chlen + j;
                    let v = m.data()[idx] - r.bias;
                    let clipped = v.clamp(-r.half_width, r.half_width);
                    if v != clipped {
                        sparse.indices.push(idx);
                        sparse.values.push(v - clipped);
                    }
                }
            }
        }

        // parallel closed-form planes on the clipped, centred tensor
        let mut planes = Vec::with_capacity(cfg.terms);
        let mut scales = Vec::with_capacity(cfg.terms);
        let mut prev_q: Vec<i64> = vec![0; m.numel()];
        let mut s_t = scale1.clone();
        for _ in 0..cfg.terms {
            let mut plane = vec![0i32; m.numel()];
            for c in 0..nch {
                let r = ranges[c];
                let s = s_t[c];
                for j in 0..chlen {
                    let idx = c * chlen + j;
                    let v = (m.data()[idx] - r.bias).clamp(-r.half_width, r.half_width);
                    let q = if s > 0.0 { (v / s).round() as i64 } else { 0 };
                    plane[idx] = (q - (levels as i64) * prev_q[idx]) as i32;
                    prev_q[idx] = q;
                }
            }
            planes.push(IntTensor::from_vec(&dims, plane));
            scales.push(s_t.clone());
            for s in s_t.iter_mut() {
                *s /= levels;
            }
        }

        SeriesExpansion { config: *cfg, dims, bias, scales, planes, sparse }
    }

    pub fn n_channels(&self) -> usize {
        self.bias.len()
    }

    fn chlen(&self) -> usize {
        let numel: usize = self.dims.iter().product();
        numel / self.n_channels()
    }

    /// Dense reconstruction `M_sa + bias·M_nsy + Σ scale_i·M̃_i`.
    pub fn reconstruct(&self) -> Tensor {
        self.reconstruct_terms(self.planes.len())
    }

    /// Reconstruction truncated to the first `terms` INT planes
    /// (Figure 4b's convergence sweep).
    pub fn reconstruct_terms(&self, terms: usize) -> Tensor {
        let chlen = self.chlen();
        let mut out = Tensor::zeros(&self.dims);
        for c in 0..self.n_channels() {
            for j in 0..chlen {
                out.data_mut()[c * chlen + j] = self.bias[c];
            }
        }
        for t in 0..terms.min(self.planes.len()) {
            let plane = &self.planes[t];
            for c in 0..self.n_channels() {
                let s = self.scales[t][c];
                if s == 0.0 {
                    continue;
                }
                for j in 0..chlen {
                    let idx = c * chlen + j;
                    out.data_mut()[idx] += s * plane.data()[idx] as f32;
                }
            }
        }
        self.sparse.add_into(&mut out);
        out
    }

    /// One dequantized term `scale_t ⊙ M̃_t` as a dense tensor.
    pub fn term_tensor(&self, t: usize) -> Tensor {
        let chlen = self.chlen();
        let mut out = Tensor::zeros(&self.dims);
        let plane = &self.planes[t];
        for c in 0..self.n_channels() {
            let s = self.scales[t][c];
            for j in 0..chlen {
                let idx = c * chlen + j;
                out.data_mut()[idx] = s * plane.data()[idx] as f32;
            }
        }
        out
    }

    /// Analytic `‖M − reconstruct()‖∞` bound: half the last scale
    /// (max over channels) — Theorem 1's exponential convergence — plus an
    /// f32-rounding floor proportional to the data magnitude (deep
    /// expansions bottom out at float precision, not zero).
    pub fn residual_bound(&self) -> f32 {
        let Some(last) = self.scales.last() else { return 0.0 };
        let s_n = last.iter().fold(0.0f32, |m, &v| m.max(v));
        let s_1 = self.scales[0].iter().fold(0.0f32, |m, &v| m.max(v));
        let bias_mag = self.bias.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
        let magnitude = s_1 * self.config.bits.half() as f32 + bias_mag;
        s_n * 0.5 + magnitude * 8.0 * f32::EPSILON + 1e-7
    }

    /// True iff every plane fits in the configured bit-width
    /// (`|M̃| ≤ 2^{X−1}`, the symmetric INT(X) envelope).
    pub fn planes_fit(&self) -> bool {
        self.planes.iter().all(|p| p.fits_signed(self.config.bits.bits + 1) && {
            let lim = self.config.bits.half();
            p.data().iter().all(|&v| -lim <= v && v <= lim)
        })
    }

    /// Total bytes to store the expansion (planes at X bits + scales/bias
    /// + sparse) — the Table 3 "Model Size" accounting.
    pub fn storage_bytes(&self) -> usize {
        let numel: usize = self.dims.iter().product();
        let plane_bits = numel * self.config.bits.bits as usize * self.planes.len();
        let meta = (self.bias.len() + self.scales.len() * self.n_channels()) * 4;
        let sparse = self.sparse.nnz() * 8; // index + f32 value
        plane_bits / 8 + meta + sparse
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Rng;

    fn randn(dims: &[usize], seed: u64) -> Tensor {
        let mut rng = Rng::seed(seed);
        Tensor::randn(dims, 1.0, &mut rng)
    }

    #[test]
    fn reconstruction_within_bound_symmetric() {
        let m = randn(&[32, 16], 1);
        for &bits in &[2u32, 4, 8] {
            for terms in 1..=4 {
                let cfg = ExpandConfig::symmetric(BitSpec::int(bits), terms);
                let e = SeriesExpansion::expand(&m, &cfg);
                let err = m.sub(&e.reconstruct()).max_abs();
                assert!(
                    err <= e.residual_bound(),
                    "bits {bits} terms {terms}: err {err} > bound {}",
                    e.residual_bound()
                );
            }
        }
    }

    #[test]
    fn scale_law_is_exact_powers() {
        let m = randn(&[8, 8], 2);
        let cfg = ExpandConfig::symmetric(BitSpec::int(4), 4);
        let e = SeriesExpansion::expand(&m, &cfg);
        for t in 1..e.scales.len() {
            for c in 0..e.n_channels() {
                assert_eq!(e.scales[t - 1][c], e.scales[t][c] * 16.0, "term {t}");
            }
        }
    }

    #[test]
    fn planes_are_within_int_range() {
        let m = randn(&[16, 16], 3);
        for &bits in &[2u32, 3, 4, 8] {
            let cfg = ExpandConfig::symmetric(BitSpec::int(bits), 3);
            let e = SeriesExpansion::expand(&m, &cfg);
            assert!(e.planes_fit(), "bits {bits}");
        }
    }

    #[test]
    fn exponential_convergence() {
        let m = randn(&[64, 8], 4);
        let cfg = ExpandConfig::symmetric(BitSpec::int(4), 1);
        let mut errs = Vec::new();
        for terms in 1..=4 {
            let e = SeriesExpansion::expand(&m, &cfg.with_terms(terms));
            errs.push(m.sub(&e.reconstruct()).max_abs());
        }
        for w in errs.windows(2) {
            // each extra INT4 term must shrink the residual by ~2^4
            assert!(w[1] <= w[0] / 8.0, "convergence too slow: {errs:?}");
        }
    }

    #[test]
    fn asymmetric_recovers_shifted_data() {
        let mut rng = Rng::seed(5);
        // data centred far from 0 — symmetric wastes range, asymmetric doesn't
        let m = Tensor::from_vec(&[256], (0..256).map(|_| 10.0 + rng.normal()).collect());
        let sym = SeriesExpansion::expand(&m, &ExpandConfig::symmetric(BitSpec::int(4), 1));
        let asym_cfg = ExpandConfig {
            symmetry: Symmetry::Asymmetric,
            ..ExpandConfig::symmetric(BitSpec::int(4), 1)
        };
        let asym = SeriesExpansion::expand(&m, &asym_cfg);
        let err_sym = m.sub(&sym.reconstruct()).max_abs();
        let err_asym = m.sub(&asym.reconstruct()).max_abs();
        assert!(err_asym < err_sym * 0.5, "asym {err_asym} vs sym {err_sym}");
        assert!((asym.bias[0] - 10.0).abs() < 1.0);
    }

    #[test]
    fn saturating_clip_exact_via_sparse() {
        // heavy-tailed data: Laplace clip + M_sa must still reconstruct exactly
        let mut rng = Rng::seed(6);
        let m = Tensor::from_vec(&[2000], (0..2000).map(|_| rng.laplace(1.0)).collect());
        let cfg = ExpandConfig::symmetric(BitSpec::int(4), 3).with_clip(Clip::Laplace);
        let e = SeriesExpansion::expand(&m, &cfg);
        assert!(e.sparse.nnz() > 0, "clip should produce a sparse residual");
        let err = m.sub(&e.reconstruct()).max_abs();
        assert!(err <= e.residual_bound(), "err {err} bound {}", e.residual_bound());
        // and the sparse part must be a small fraction of elements
        assert!(e.sparse.nnz() < 400, "M_sa too dense: {}", e.sparse.nnz());
    }

    #[test]
    fn per_channel_beats_per_tensor_on_mixed_scales() {
        let mut rng = Rng::seed(7);
        // channel 0 has tiny weights, channel 1 huge — per-tensor wastes bits
        let mut data = Vec::new();
        for _ in 0..64 {
            data.push(rng.normal() * 0.01);
        }
        for _ in 0..64 {
            data.push(rng.normal() * 10.0);
        }
        let m = Tensor::from_vec(&[2, 64], data);
        let pt = SeriesExpansion::expand(&m, &ExpandConfig::symmetric(BitSpec::int(4), 1));
        let mut pc_cfg = ExpandConfig::symmetric(BitSpec::int(4), 1);
        pc_cfg.channel_axis = Some(0);
        let pc = SeriesExpansion::expand(&m, &pc_cfg);
        // error on the small channel
        let err = |e: &SeriesExpansion| {
            m.sub(&e.reconstruct()).data()[..64].iter().fold(0.0f32, |a, &v| a.max(v.abs()))
        };
        assert!(err(&pc) < err(&pt) / 10.0, "pc {} pt {}", err(&pc), err(&pt));
    }

    #[test]
    fn parallel_form_matches_sequential_residual_recursion() {
        // DESIGN.md §7 invariant 6: closed-form planes == greedy residual quant
        let m = randn(&[128], 8);
        let cfg = ExpandConfig::symmetric(BitSpec::int(4), 3);
        let e = SeriesExpansion::expand(&m, &cfg);
        // sequential reference
        let half = 8.0f32;
        let s1 = m.max_abs() / half;
        let mut resid = m.clone();
        let mut scale = s1;
        for t in 0..3 {
            let plane: Vec<i32> =
                resid.data().iter().map(|&v| (v / scale).round() as i32).collect();
            assert_eq!(plane, e.planes[t].data(), "term {t} differs");
            let deq: Vec<f32> = plane.iter().map(|&q| q as f32 * scale).collect();
            resid = Tensor::from_vec(&[128], resid.data().iter().zip(&deq).map(|(a, b)| a - b).collect());
            scale /= 16.0;
        }
    }

    #[test]
    fn zero_tensor_expansion_is_stable() {
        let m = Tensor::zeros(&[4, 4]);
        let e = SeriesExpansion::expand(&m, &ExpandConfig::symmetric(BitSpec::int(4), 3));
        assert_eq!(e.reconstruct(), m);
        assert!(e.residual_bound() <= 1e-6);
        assert!(e.planes.iter().all(|p| p.data().iter().all(|&v| v == 0)));
    }

    #[test]
    fn storage_accounting_scales_with_bits_and_terms() {
        let m = randn(&[64, 64], 9);
        let e2 = SeriesExpansion::expand(&m, &ExpandConfig::symmetric(BitSpec::int(2), 1));
        let e4 = SeriesExpansion::expand(&m, &ExpandConfig::symmetric(BitSpec::int(4), 1));
        let e4x2 = SeriesExpansion::expand(&m, &ExpandConfig::symmetric(BitSpec::int(4), 2));
        assert!(e2.storage_bytes() < e4.storage_bytes());
        assert!(e4.storage_bytes() < e4x2.storage_bytes());
        // INT4 single term of 4096 params ≈ 2048 bytes + metadata
        assert!(e4.storage_bytes() >= 2048 && e4.storage_bytes() < 2200);
    }

    #[test]
    fn property_reconstruction_bound_random_tensors() {
        use crate::util::prop::{forall, no_shrink, PropConfig};
        forall(
            PropConfig { cases: 40, seed: 0xABCD, max_shrink: 0 },
            |r| {
                let rows = 1 + r.below(8);
                let cols = 1 + r.below(32);
                let bits = [2u32, 3, 4, 8][r.below(4)];
                let terms = 1 + r.below(4);
                let scale = 10f32.powi(r.below(5) as i32 - 2);
                let mut rng2 = r.fork(1);
                let m = Tensor::randn(&[rows, cols], scale, &mut rng2);
                (m, bits, terms)
            },
            no_shrink,
            |(m, bits, terms)| {
                let cfg = ExpandConfig::symmetric(BitSpec::int(*bits), *terms);
                let e = SeriesExpansion::expand(m, &cfg);
                let err = m.sub(&e.reconstruct()).max_abs();
                if err <= e.residual_bound() && e.planes_fit() {
                    Ok(())
                } else {
                    Err(format!("err {err} bound {} fit {}", e.residual_bound(), e.planes_fit()))
                }
            },
        );
    }
}
