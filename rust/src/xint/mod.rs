//! The paper's contribution: low-bit series expansion of FP tensors,
//! layers and models (Theorems 1–2, Eqs. 3–8).
//!
//! * [`quantizer`] — single-step integer quantization variants (symmetric /
//!   asymmetric × saturating / non-saturating, analytic Laplace clipping).
//! * [`expansion`] — Theorem 1: `M = M_sa + bias·M_nsy + Σ scale_i·M̃_i`
//!   with `scale_i = 2^X · scale_{i+1}`, built via the §4 parallel closed
//!   form; per-tensor or per-channel.
//! * [`gemm`] — Eq. 3: the expanded low-bit GEMM with i32 accumulation,
//!   rank-1 `M_nsy` fast path and sparse `M_sa` path.
//! * [`kernel`] — the packed execution tier under [`gemm`]: basis planes
//!   narrowed to i8 once and reused across the grid, an AVX2 `maddubs`
//!   micro-kernel behind runtime dispatch (the portable fallback is
//!   bit-identical), and row-block parallelism over a persistent
//!   worker set.
//! * [`layer`] — Eq. 4: expanded linear / conv layers with the paper's
//!   deployment policy (per-channel weights, 8-bit first/last layer,
//!   weight-term upper bound from the §4 total-differential criterion).
//! * [`budget`] — the runtime budget hierarchy: [`TermBudget`] caps one
//!   layer's Eq. 3 term grid (executed largest-scale-first so any
//!   prefix is the best available approximation, with the §5.3
//!   scale-product stop), and [`BudgetPlan`] carries one budget per
//!   layer plus a global grid ceiling through the forward stack.
//! * [`planner`] — the [`BudgetPlanner`]: sensitivity-profiled greedy
//!   allocation of a tier's total grid ceiling across layers (per-layer
//!   monitor curves, §5.1 first/last exemption folded in).
//! * [`abelian`] — AbelianAdd / AbelianMul, the Abelian group over
//!   isomorphic basis models, and the AllReduce-style reduction.
//! * [`mixed`] — mixed-precision planner + model-size accounting (Table 3).
//! * [`monitor`] — expansion-count auto-stop rule and convergence traces
//!   (Figure 4b).

pub mod abelian;
pub mod auto;
pub mod budget;
pub mod expansion;
pub mod gemm;
pub mod kernel;
pub mod layer;
pub mod mixed;
pub mod monitor;
pub mod planner;
pub mod quantizer;

pub use abelian::{abelian_reduce, AbelianMul, LinearModel};
pub use auto::{quantize_model_auto, AutoConfig};
pub use budget::{BudgetPlan, ForwardStats, LayerTrace, TermBudget};
pub use expansion::{ExpandConfig, SeriesExpansion, SparseTensor};
pub use gemm::{int_gemm_a_bt, xint_linear_forward, xint_linear_forward_budgeted, ExpandedWeight};
pub use kernel::{active_kernel, Kernel, KernelPool, PackedPlane};
pub use layer::{LayerPolicy, XintConv2d, XintLinear};
pub use mixed::{greedy_allocate, model_size_bytes, MixedPlan, MixedPlanner};
pub use monitor::{ConfigMismatch, ExpansionMonitor, LayerSeries};
pub use planner::{BudgetPlanner, LayerGridProfile};
pub use quantizer::{Clip, Symmetry};

/// Integer bit-width `X` of every basis plane (the paper's `INT(X)`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct BitSpec {
    pub bits: u32,
}

impl BitSpec {
    pub fn int(bits: u32) -> Self {
        assert!((1..=16).contains(&bits), "supported bit-widths: 1..=16");
        BitSpec { bits }
    }

    /// Quantization levels per term: `2^X`.
    pub fn levels(&self) -> i64 {
        1i64 << self.bits
    }

    /// Symmetric half-range `2^{X-1}`.
    pub fn half(&self) -> i32 {
        1i32 << (self.bits - 1)
    }
}
