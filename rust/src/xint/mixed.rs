//! Mixed-precision planning + model-size accounting (Table 3's
//! `2/Mix(2/4/8)` rows).
//!
//! The planner measures per-layer sensitivity (output MSE on a probe batch
//! when only that layer is quantized at each candidate bit-width) and
//! greedily assigns higher widths to the most sensitive layers until a
//! size budget is met — weights stay at the base width (2-bit in the
//! paper's mix), activations get 2/4/8 by sensitivity.

use super::layer::LayerPolicy;

/// Candidate description of one quantizable layer for the planner.
#[derive(Clone, Debug)]
pub struct LayerInfo {
    pub name: String,
    pub params: usize,
    /// sensitivity[b] = output error when this layer runs at `bits[b]`
    pub sensitivity: Vec<f64>,
}

/// The planner's bit-width menu.
pub const MIX_BITS: [u32; 3] = [2, 4, 8];

/// A resolved plan: per-layer (w_bits, a_bits).
#[derive(Clone, Debug, PartialEq)]
pub struct MixedPlan {
    pub layers: Vec<(String, u32, u32)>,
}

impl MixedPlan {
    /// Total weight storage in bytes under this plan (+32-bit scale per
    /// channel is charged by the caller via expansion storage; this is the
    /// headline "model size" number, paper-style: bits × params / 8).
    pub fn size_bytes(&self, params: &[usize]) -> usize {
        assert_eq!(params.len(), self.layers.len());
        self.layers
            .iter()
            .zip(params)
            .map(|((_, wb, _), &p)| (p * *wb as usize).div_ceil(8))
            .collect::<Vec<_>>()
            .iter()
            .sum()
    }

    pub fn policy_for(&self, idx: usize) -> LayerPolicy {
        let (_, wb, ab) = self.layers[idx];
        LayerPolicy::new(wb, ab)
    }
}

/// Paper-style model size: `bits/8 × params` bytes (uniform width).
pub fn model_size_bytes(params: usize, bits: u32) -> usize {
    (params * bits as usize).div_ceil(8)
}

/// The greedy sensitivity-ordered upgrade loop shared by the
/// mixed-precision planner and the serve-time
/// [`BudgetPlanner`](super::planner::BudgetPlanner): starting with every
/// candidate at level 0, repeatedly upgrade the candidate with the best
/// error-reduction per unit step cost, stopping when the first upgrade
/// would push `total_cost` past `budget` or no upgrade has positive
/// gain. Returns the chosen level per candidate.
///
/// The upgrade *order* depends only on the gain/cost ratios, never on
/// `budget` — so allocations at growing budgets are nested prefixes of
/// one upgrade sequence, which is what makes planned budgets monotone
/// (the Theorem 1 prefix argument at allocation granularity).
///
/// * `max_level(i)` — number of levels candidate `i` has (choices are
///   `0..max_level(i)`).
/// * `gain(i, level)` — error reduction of moving `i` from `level` to
///   `level + 1`.
/// * `step_cost(i, level)` — cost units that move adds (floored at 1
///   for the ratio).
/// * `total_cost(levels)` — full-assignment cost checked against
///   `budget` after each tentative upgrade (lets callers keep non-linear
///   cost models, e.g. byte rounding).
pub fn greedy_allocate(
    n: usize,
    max_level: impl Fn(usize) -> usize,
    gain: impl Fn(usize, usize) -> f64,
    step_cost: impl Fn(usize, usize) -> usize,
    total_cost: impl Fn(&[usize]) -> usize,
    budget: usize,
) -> Vec<usize> {
    let mut choice: Vec<usize> = vec![0; n];
    loop {
        let mut best: Option<(usize, f64)> = None;
        for i in 0..n {
            if choice[i] + 1 >= max_level(i) {
                continue;
            }
            let ratio = gain(i, choice[i]) / step_cost(i, choice[i]).max(1) as f64;
            if ratio > 0.0 && best.map(|(_, r)| ratio > r).unwrap_or(true) {
                best = Some((i, ratio));
            }
        }
        let Some((i, _)) = best else { break };
        choice[i] += 1;
        if total_cost(&choice) > budget {
            choice[i] -= 1;
            break;
        }
    }
    choice
}

/// Greedy sensitivity-ordered mixed-precision planner.
pub struct MixedPlanner {
    pub w_bits: u32,
    /// activation size is free at serve time; budget constrains weights +
    /// the *activation term count* proxy: widening A costs compute, modeled
    /// as `a_bits/2` weight-equivalent bits here (paper gives no formula;
    /// DESIGN.md records this as a substitution)
    pub budget_bytes: usize,
}

impl MixedPlanner {
    pub fn plan(&self, layers: &[LayerInfo]) -> MixedPlan {
        // the shared greedy loop: start everything at the lowest width,
        // repeatedly upgrade the layer with the best
        // error-reduction / byte-cost ratio while under budget
        let choice = greedy_allocate(
            layers.len(),
            |_| MIX_BITS.len(),
            |i, c| layers[i].sensitivity[c] - layers[i].sensitivity[c + 1],
            |i, c| (layers[i].params * (MIX_BITS[c + 1] - MIX_BITS[c]) as usize / 2).div_ceil(8),
            |choice| {
                choice
                    .iter()
                    .zip(layers)
                    .map(|(&c, l)| {
                        let wbits = self.w_bits as usize;
                        let abits = MIX_BITS[c] as usize;
                        (l.params * wbits).div_ceil(8) + (l.params * abits / 2).div_ceil(8)
                    })
                    .sum()
            },
            self.budget_bytes,
        );
        MixedPlan {
            layers: layers
                .iter()
                .zip(&choice)
                .map(|(l, &c)| (l.name.clone(), self.w_bits, MIX_BITS[c]))
                .collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn layer(name: &str, params: usize, sens: [f64; 3]) -> LayerInfo {
        LayerInfo { name: name.into(), params, sensitivity: sens.to_vec() }
    }

    #[test]
    fn size_accounting() {
        assert_eq!(model_size_bytes(1000, 4), 500);
        assert_eq!(model_size_bytes(1000, 2), 250);
        assert_eq!(model_size_bytes(3, 4), 2); // ceil
    }

    #[test]
    fn planner_prefers_sensitive_layers() {
        let layers = vec![
            layer("robust", 1000, [0.1, 0.08, 0.07]),
            layer("fragile", 1000, [9.0, 1.0, 0.1]),
        ];
        let p = MixedPlanner { w_bits: 2, budget_bytes: 1200 }.plan(&layers);
        let frag = p.layers.iter().find(|l| l.0 == "fragile").unwrap();
        let rob = p.layers.iter().find(|l| l.0 == "robust").unwrap();
        assert!(frag.2 > rob.2, "fragile {:?} robust {:?}", frag, rob);
    }

    #[test]
    fn planner_respects_budget() {
        let layers: Vec<LayerInfo> =
            (0..4).map(|i| layer(&format!("l{i}"), 10_000, [5.0, 1.0, 0.1])).collect();
        // tight budget: 2-bit weights + 2-bit act proxy ≈ 10k*(2+1)/8 per layer
        let tight = MixedPlanner { w_bits: 2, budget_bytes: 16_000 };
        let p = tight.plan(&layers);
        // all weights stay at base width
        assert!(p.layers.iter().all(|l| l.1 == 2));
        let loose = MixedPlanner { w_bits: 2, budget_bytes: 1_000_000 }.plan(&layers);
        // plenty of budget: everything upgrades to 8-bit activations
        assert!(loose.layers.iter().all(|l| l.2 == 8), "{:?}", loose.layers);
        // and the loose plan dominates in total activation width
        let sum = |pl: &MixedPlan| pl.layers.iter().map(|l| l.2).sum::<u32>();
        assert!(sum(&loose) >= sum(&p));
    }

    #[test]
    fn plan_size_bytes_matches_manual() {
        let plan = MixedPlan {
            layers: vec![("a".into(), 2, 4), ("b".into(), 2, 8)],
        };
        assert_eq!(plan.size_bytes(&[100, 200]), 25 + 50);
    }

    #[test]
    fn greedy_allocations_are_nested_in_budget() {
        // the upgrade order is budget-independent, so a smaller budget's
        // allocation is a coordinatewise prefix of a larger one — the
        // property the serve-time BudgetPlanner's monotonicity rides on
        let gains = [[5.0, 1.0], [9.0, 4.0], [0.5, 0.2]];
        let alloc = |budget: usize| {
            greedy_allocate(
                3,
                |_| 3,
                |i, c| gains[i][c],
                |_, _| 1,
                |choice| choice.iter().sum::<usize>(),
                budget,
            )
        };
        let mut prev = alloc(0);
        assert_eq!(prev, vec![0, 0, 0]);
        for budget in 1..=6 {
            let cur = alloc(budget);
            assert!(
                prev.iter().zip(&cur).all(|(&a, &b)| a <= b),
                "not nested at {budget}: {prev:?} vs {cur:?}"
            );
            assert!(cur.iter().sum::<usize>() <= budget);
            prev = cur;
        }
        // with room for everything, all candidates saturate
        assert_eq!(alloc(100), vec![2, 2, 2]);
        // best gain-per-cost goes first
        assert_eq!(alloc(1), vec![0, 1, 0]);
    }

    #[test]
    fn policy_for_roundtrip() {
        let plan = MixedPlan { layers: vec![("a".into(), 2, 8)] };
        let pol = plan.policy_for(0);
        assert_eq!(pol.w_bits.bits, 2);
        assert_eq!(pol.a_bits.bits, 8);
    }
}
