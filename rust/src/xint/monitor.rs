//! Expansion-count convergence monitor — the §5.3 auto-stop rule
//! ("when the maximum difference is less than 1e-4, the number of
//! expansions is optimal") and the data series behind Figure 4b.

use super::expansion::{ExpandConfig, SeriesExpansion};
use crate::tensor::Tensor;

/// Records max-residual per expansion count for a stream of tensors.
#[derive(Clone, Debug, Default)]
pub struct ExpansionMonitor {
    /// max |x - recon_t(x)| seen, indexed by term count − 1
    pub max_diff: Vec<f32>,
    pub samples: usize,
}

impl ExpansionMonitor {
    pub fn new() -> Self {
        Self::default()
    }

    /// Observe one tensor under `cfg` for 1..=cfg.terms truncations.
    ///
    /// Each truncation's reconstruction is built incrementally from the
    /// previous prefix (`recon_t = recon_{t-1} + scale_t·M̃_t`), so one
    /// observation costs O(terms·numel) instead of the naive
    /// O(terms²·numel) of re-reconstructing every prefix from scratch.
    pub fn observe(&mut self, x: &Tensor, cfg: &ExpandConfig) {
        let e = SeriesExpansion::expand(x, cfg);
        if self.max_diff.len() < cfg.terms {
            self.max_diff.resize(cfg.terms, 0.0);
        }
        // term count 0 = bias + sparse saturation residual only
        let mut recon = e.reconstruct_terms(0);
        for t in 1..=cfg.terms {
            recon.axpy(1.0, &e.term_tensor(t - 1));
            let diff = x.sub(&recon).max_abs();
            self.max_diff[t - 1] = self.max_diff[t - 1].max(diff);
        }
        self.samples += 1;
    }

    /// The paper's rule: smallest term count whose max diff < `tol`
    /// (default 1e-4); `None` if never reached within the observed range.
    pub fn optimal_terms(&self, tol: f32) -> Option<usize> {
        self.max_diff.iter().position(|&d| d < tol).map(|i| i + 1)
    }

    /// The (terms, max_diff) series — Figure 4b's blue line.
    pub fn series(&self) -> Vec<(usize, f32)> {
        self.max_diff.iter().enumerate().map(|(i, &d)| (i + 1, d)).collect()
    }

    /// Observed max-residual at a given truncation (`None` outside the
    /// observed range) — the QoS controller's estimated precision loss.
    pub fn max_diff_at(&self, terms: usize) -> Option<f32> {
        if terms == 0 {
            return None;
        }
        self.max_diff.get(terms - 1).copied()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Rng;
    use crate::xint::{BitSpec, ExpandConfig};

    #[test]
    fn monitor_series_decreases() {
        let mut rng = Rng::seed(51);
        let mut mon = ExpansionMonitor::new();
        let cfg = ExpandConfig::symmetric(BitSpec::int(4), 5);
        for _ in 0..4 {
            mon.observe(&Tensor::randn(&[16, 16], 1.0, &mut rng), &cfg);
        }
        assert_eq!(mon.samples, 4);
        let s = mon.series();
        assert_eq!(s.len(), 5);
        for w in s.windows(2) {
            assert!(w[1].1 <= w[0].1, "non-monotone {s:?}");
        }
    }

    #[test]
    fn optimal_terms_matches_rule() {
        let mut rng = Rng::seed(52);
        let mut mon = ExpansionMonitor::new();
        let cfg = ExpandConfig::symmetric(BitSpec::int(4), 6);
        mon.observe(&Tensor::randn(&[32, 32], 1.0, &mut rng), &cfg);
        let n = mon.optimal_terms(1e-4).expect("INT4×6 reaches 1e-4");
        // INT4: residual ≈ max/2^(4t+1); max≈4 ⇒ need ~4 terms
        assert!((3..=5).contains(&n), "optimal {n}");
        // a stricter tolerance needs at least as many terms
        if let Some(n9) = mon.optimal_terms(1e-6) {
            assert!(n9 >= n);
        }
    }

    #[test]
    fn incremental_observe_matches_full_reconstruction() {
        let mut rng = Rng::seed(54);
        let x = Tensor::randn(&[24, 8], 1.0, &mut rng);
        let cfg = ExpandConfig::symmetric(BitSpec::int(4), 5);
        let mut mon = ExpansionMonitor::new();
        mon.observe(&x, &cfg);
        let e = SeriesExpansion::expand(&x, &cfg);
        for t in 1..=5 {
            let full = x.sub(&e.reconstruct_terms(t)).max_abs();
            let inc = mon.max_diff_at(t).unwrap();
            assert!(
                (full - inc).abs() <= 1e-6 * (1.0 + full.abs()),
                "t {t}: incremental {inc} vs full {full}"
            );
        }
        assert_eq!(mon.max_diff_at(0), None);
        assert_eq!(mon.max_diff_at(9), None);
    }

    #[test]
    fn unreached_tolerance_is_none() {
        let mut mon = ExpansionMonitor::new();
        let cfg = ExpandConfig::symmetric(BitSpec::int(2), 1);
        let mut rng = Rng::seed(53);
        mon.observe(&Tensor::randn(&[8, 8], 1.0, &mut rng), &cfg);
        assert_eq!(mon.optimal_terms(1e-12), None);
    }
}
