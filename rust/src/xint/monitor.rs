//! Expansion-count convergence monitor — the §5.3 auto-stop rule
//! ("when the maximum difference is less than 1e-4, the number of
//! expansions is optimal") and the data series behind Figure 4b.
//!
//! Observations are **config-guarded**: a series mixes only samples
//! taken under one [`ExpandConfig`] (bits/terms/symmetry/clip), because
//! a `max_diff` curve aggregated across configs is meaningless — an
//! INT2 residual folded into an INT8 series would poison every
//! calibration downstream. The first observation records the config;
//! later mismatches return a [`ConfigMismatch`] error.
//!
//! Besides the aggregate series (pool-prefix calibration), the monitor
//! keeps **per-layer-keyed** series ([`ExpansionMonitor::observe_layer`]):
//! the paper's Theorem 1 converges per *tensor*, so each layer has its
//! own convergence curve — exactly the sensitivity profile the
//! [`BudgetPlanner`](super::planner::BudgetPlanner) allocates a grid
//! ceiling against. Layer keys are independent: different layers may
//! legitimately observe under different configs (§5.1 gives first/last
//! layers an 8-bit policy).

use super::expansion::{ExpandConfig, SeriesExpansion};
use crate::tensor::Tensor;
use std::collections::BTreeMap;

/// An observation offered under a different [`ExpandConfig`] than the
/// one a series was started with.
#[derive(Clone, Debug, PartialEq)]
pub struct ConfigMismatch {
    /// the layer key, `None` for the aggregate series
    pub layer: Option<usize>,
    /// config recorded on first observe
    pub recorded: ExpandConfig,
    /// config of the rejected observation
    pub offered: ExpandConfig,
}

impl std::fmt::Display for ConfigMismatch {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let key = match self.layer {
            Some(i) => format!("layer {i}"),
            None => "aggregate".to_string(),
        };
        write!(
            f,
            "ExpansionMonitor {key} series started under {:?} rejects an observation \
             under {:?}: one series, one config",
            self.recorded, self.offered
        )
    }
}

impl std::error::Error for ConfigMismatch {}

/// One convergence series: max-residual per truncation count plus the
/// config it was observed under.
#[derive(Clone, Debug, Default)]
pub struct LayerSeries {
    /// max |x - recon_t(x)| seen, indexed by term count − 1
    pub max_diff: Vec<f32>,
    pub samples: usize,
    cfg: Option<ExpandConfig>,
}

impl LayerSeries {
    fn observe(
        &mut self,
        x: &Tensor,
        cfg: &ExpandConfig,
        layer: Option<usize>,
    ) -> Result<(), ConfigMismatch> {
        match &self.cfg {
            Some(recorded) if recorded != cfg => {
                return Err(ConfigMismatch { layer, recorded: *recorded, offered: *cfg });
            }
            Some(_) => {}
            None => self.cfg = Some(*cfg),
        }
        let e = SeriesExpansion::expand(x, cfg);
        if self.max_diff.len() < cfg.terms {
            self.max_diff.resize(cfg.terms, 0.0);
        }
        // term count 0 = bias + sparse saturation residual only; each
        // truncation's reconstruction is built incrementally from the
        // previous prefix (`recon_t = recon_{t-1} + scale_t·M̃_t`), so
        // one observation costs O(terms·numel) instead of O(terms²·numel)
        let mut recon = e.reconstruct_terms(0);
        for t in 1..=cfg.terms {
            recon.axpy(1.0, &e.term_tensor(t - 1));
            let diff = x.sub(&recon).max_abs();
            self.max_diff[t - 1] = self.max_diff[t - 1].max(diff);
        }
        self.samples += 1;
        Ok(())
    }

    /// The config this series was started under (`None` if empty).
    pub fn config(&self) -> Option<&ExpandConfig> {
        self.cfg.as_ref()
    }

    /// The §5.3 rule on this series: smallest term count whose max diff
    /// is under `tol`; `None` if never reached in the observed range.
    pub fn optimal_terms(&self, tol: f32) -> Option<usize> {
        self.max_diff.iter().position(|&d| d < tol).map(|i| i + 1)
    }

    /// Observed max-residual at `terms` (`None` outside the range).
    pub fn max_diff_at(&self, terms: usize) -> Option<f32> {
        if terms == 0 {
            return None;
        }
        self.max_diff.get(terms - 1).copied()
    }
}

/// Records max-residual per expansion count for a stream of tensors —
/// one aggregate series plus one series per layer key.
#[derive(Clone, Debug, Default)]
pub struct ExpansionMonitor {
    aggregate: LayerSeries,
    layers: BTreeMap<usize, LayerSeries>,
}

impl ExpansionMonitor {
    pub fn new() -> Self {
        Self::default()
    }

    /// Observe one tensor under `cfg` for 1..=cfg.terms truncations in
    /// the aggregate series. Errors when `cfg` differs from the config
    /// the series was started with.
    pub fn observe(&mut self, x: &Tensor, cfg: &ExpandConfig) -> Result<(), ConfigMismatch> {
        self.aggregate.observe(x, cfg, None)
    }

    /// Observe one tensor into the series keyed by `layer` (the
    /// quantizable-layer position). Keys are independent — each layer
    /// records its own config on first observe and rejects mismatches;
    /// the aggregate series is untouched (layers under §5.1 policies
    /// legitimately differ in config, which the aggregate must not mix).
    pub fn observe_layer(
        &mut self,
        layer: usize,
        x: &Tensor,
        cfg: &ExpandConfig,
    ) -> Result<(), ConfigMismatch> {
        self.layers.entry(layer).or_default().observe(x, cfg, Some(layer))
    }

    /// Aggregate max-residual series (indexed by term count − 1).
    pub fn max_diff(&self) -> &[f32] {
        &self.aggregate.max_diff
    }

    /// Aggregate observation count.
    pub fn samples(&self) -> usize {
        self.aggregate.samples
    }

    /// The paper's rule on the aggregate series: smallest term count
    /// whose max diff < `tol` (default 1e-4); `None` if never reached
    /// within the observed range.
    pub fn optimal_terms(&self, tol: f32) -> Option<usize> {
        self.aggregate.optimal_terms(tol)
    }

    /// The aggregate (terms, max_diff) series — Figure 4b's blue line.
    pub fn series(&self) -> Vec<(usize, f32)> {
        self.aggregate.max_diff.iter().enumerate().map(|(i, &d)| (i + 1, d)).collect()
    }

    /// Aggregate max-residual at a given truncation (`None` outside the
    /// observed range) — the QoS controller's estimated precision loss.
    pub fn max_diff_at(&self, terms: usize) -> Option<f32> {
        self.aggregate.max_diff_at(terms)
    }

    /// The series observed for `layer`, if any.
    pub fn layer_series(&self, layer: usize) -> Option<&LayerSeries> {
        self.layers.get(&layer)
    }

    /// §5.3 rule on one layer's series (`None` when the layer was never
    /// observed or never reached `tol`).
    pub fn optimal_terms_layer(&self, layer: usize, tol: f32) -> Option<usize> {
        self.layers.get(&layer).and_then(|s| s.optimal_terms(tol))
    }

    /// One layer's max-residual at `terms`.
    pub fn max_diff_at_layer(&self, layer: usize, terms: usize) -> Option<f32> {
        self.layers.get(&layer).and_then(|s| s.max_diff_at(terms))
    }

    /// Number of distinct layer keys observed.
    pub fn layer_count(&self) -> usize {
        self.layers.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Rng;
    use crate::xint::{BitSpec, ExpandConfig};

    #[test]
    fn monitor_series_decreases() {
        let mut rng = Rng::seed(51);
        let mut mon = ExpansionMonitor::new();
        let cfg = ExpandConfig::symmetric(BitSpec::int(4), 5);
        for _ in 0..4 {
            mon.observe(&Tensor::randn(&[16, 16], 1.0, &mut rng), &cfg).unwrap();
        }
        assert_eq!(mon.samples(), 4);
        let s = mon.series();
        assert_eq!(s.len(), 5);
        for w in s.windows(2) {
            assert!(w[1].1 <= w[0].1, "non-monotone {s:?}");
        }
    }

    #[test]
    fn optimal_terms_matches_rule() {
        let mut rng = Rng::seed(52);
        let mut mon = ExpansionMonitor::new();
        let cfg = ExpandConfig::symmetric(BitSpec::int(4), 6);
        mon.observe(&Tensor::randn(&[32, 32], 1.0, &mut rng), &cfg).unwrap();
        let n = mon.optimal_terms(1e-4).expect("INT4×6 reaches 1e-4");
        // INT4: residual ≈ max/2^(4t+1); max≈4 ⇒ need ~4 terms
        assert!((3..=5).contains(&n), "optimal {n}");
        // a stricter tolerance needs at least as many terms
        if let Some(n9) = mon.optimal_terms(1e-6) {
            assert!(n9 >= n);
        }
    }

    #[test]
    fn incremental_observe_matches_full_reconstruction() {
        let mut rng = Rng::seed(54);
        let x = Tensor::randn(&[24, 8], 1.0, &mut rng);
        let cfg = ExpandConfig::symmetric(BitSpec::int(4), 5);
        let mut mon = ExpansionMonitor::new();
        mon.observe(&x, &cfg).unwrap();
        let e = SeriesExpansion::expand(&x, &cfg);
        for t in 1..=5 {
            let full = x.sub(&e.reconstruct_terms(t)).max_abs();
            let inc = mon.max_diff_at(t).unwrap();
            assert!(
                (full - inc).abs() <= 1e-6 * (1.0 + full.abs()),
                "t {t}: incremental {inc} vs full {full}"
            );
        }
        assert_eq!(mon.max_diff_at(0), None);
        assert_eq!(mon.max_diff_at(9), None);
    }

    #[test]
    fn unreached_tolerance_is_none() {
        let mut mon = ExpansionMonitor::new();
        let cfg = ExpandConfig::symmetric(BitSpec::int(2), 1);
        let mut rng = Rng::seed(53);
        mon.observe(&Tensor::randn(&[8, 8], 1.0, &mut rng), &cfg).unwrap();
        assert_eq!(mon.optimal_terms(1e-12), None);
    }

    #[test]
    fn mixed_configs_are_rejected_not_aggregated() {
        let mut rng = Rng::seed(55);
        let mut mon = ExpansionMonitor::new();
        let cfg4 = ExpandConfig::symmetric(BitSpec::int(4), 5);
        let cfg8 = ExpandConfig::symmetric(BitSpec::int(8), 5);
        let x = Tensor::randn(&[8, 8], 1.0, &mut rng);
        mon.observe(&x, &cfg4).unwrap();
        let before = mon.max_diff().to_vec();
        let err = mon.observe(&x, &cfg8).expect_err("mixed configs must be rejected");
        assert_eq!(err.layer, None);
        assert_eq!(err.recorded, cfg4);
        assert_eq!(err.offered, cfg8);
        // the rejected observation must not have touched the series
        assert_eq!(mon.max_diff(), &before[..]);
        assert_eq!(mon.samples(), 1);
        // same config keeps working
        mon.observe(&x, &cfg4).unwrap();
        assert_eq!(mon.samples(), 2);
        // a differing term count is a config mismatch too
        let cfg4_short = ExpandConfig::symmetric(BitSpec::int(4), 3);
        assert!(mon.observe(&x, &cfg4_short).is_err());
    }

    #[test]
    fn layer_series_are_keyed_independently() {
        let mut rng = Rng::seed(56);
        let mut mon = ExpansionMonitor::new();
        let cfg4 = ExpandConfig::activations(BitSpec::int(4), 4);
        let cfg8 = ExpandConfig::activations(BitSpec::int(8), 1);
        // big activations on layer 0, small on layer 1, 8-bit on layer 2
        // — three independent series, two different configs
        mon.observe_layer(0, &Tensor::randn(&[8, 16], 4.0, &mut rng), &cfg4).unwrap();
        mon.observe_layer(1, &Tensor::randn(&[8, 16], 0.05, &mut rng), &cfg4).unwrap();
        mon.observe_layer(2, &Tensor::randn(&[8, 16], 1.0, &mut rng), &cfg8).unwrap();
        assert_eq!(mon.layer_count(), 3);
        assert_eq!(mon.samples(), 0, "layer observes never touch the aggregate");
        let d0 = mon.max_diff_at_layer(0, 1).unwrap();
        let d1 = mon.max_diff_at_layer(1, 1).unwrap();
        assert!(d0 > d1, "larger activations converge slower: {d0} vs {d1}");
        // per-layer optimal terms follow each layer's own curve
        let n0 = mon.optimal_terms_layer(0, 1e-3).unwrap_or(99);
        let n1 = mon.optimal_terms_layer(1, 1e-3).unwrap_or(99);
        assert!(n0 >= n1, "sensitive layer needs at least as many terms: {n0} vs {n1}");
        assert_eq!(mon.optimal_terms_layer(7, 1e-3), None, "unobserved key");
        assert_eq!(mon.layer_series(2).unwrap().config(), Some(&cfg8));
        // per-key config guard: layer 0 rejects the 8-bit config while
        // layer 2 keeps accepting it
        let err = mon
            .observe_layer(0, &Tensor::randn(&[8, 16], 1.0, &mut rng), &cfg8)
            .expect_err("per-key mismatch");
        assert_eq!(err.layer, Some(0));
        mon.observe_layer(2, &Tensor::randn(&[8, 16], 1.0, &mut rng), &cfg8).unwrap();
        assert_eq!(mon.layer_series(2).unwrap().samples, 2);
    }
}
