//! Serve-time per-layer budget planner — sensitivity-profiled
//! allocation of a grid-term ceiling across a model's layers.
//!
//! The paper's expansion converges per *tensor* (§4, Theorem 1), so
//! layers converge at different rates: a uniform per-layer cap
//! overspends grid terms on robust layers and starves sensitive ones.
//! The [`BudgetPlanner`] takes the per-layer convergence curves a
//! per-layer [`ExpansionMonitor`](super::monitor::ExpansionMonitor)
//! observed during calibration and greedily allocates a tier's **total**
//! `(i, j)` grid-term ceiling across layers by marginal max-diff gain —
//! the same sensitivity-ordered loop the mixed-precision planner uses
//! for bit-widths ([`greedy_allocate`](super::mixed::greedy_allocate)),
//! applied to activation term counts. The §5.1 exemption is folded in:
//! 8-bit first/last layers (and FP-fallback grouped convs, which have
//! no INT grid to truncate) stay at a full budget and are not charged
//! against the ceiling.

use super::budget::{BudgetPlan, TermBudget};
use super::mixed::greedy_allocate;

/// What the planner knows about one quantizable layer (depth-first
/// position order, matching `quantize_model`'s traversal).
#[derive(Clone, Debug)]
pub struct LayerGridProfile {
    /// INT weight terms `k` actually held by the layer (the grid's `i`
    /// axis extent — and the grid cost of one activation term)
    pub w_terms: usize,
    /// activation terms `t` the layer's policy expands (the `j` axis)
    pub a_terms: usize,
    /// §5.1 exemption: pinned exact, never truncated, not charged
    /// against the grid ceiling (8-bit first/last layers, FP-fallback
    /// grouped convs)
    pub exempt: bool,
    /// observed max-residual of this layer's input expansion at
    /// `1..=a_terms` activation terms (the per-layer monitor series);
    /// empty means unprofiled — the layer then stays at the 1-term
    /// floor, the conservative-cost choice
    pub max_diff: Vec<f32>,
}

impl LayerGridProfile {
    /// Marginal gain of upgrading from `level + 1` to `level + 2`
    /// activation terms (levels are 0-based term counts minus one).
    fn gain(&self, level: usize) -> f64 {
        let cur = self.max_diff.get(level).copied().unwrap_or(0.0) as f64;
        let next = self.max_diff.get(level + 1).copied().unwrap_or(0.0) as f64;
        (cur - next).max(0.0)
    }
}

/// Greedy sensitivity-ordered allocator of one total grid-term ceiling.
#[derive(Clone, Copy, Debug)]
pub struct BudgetPlanner {
    /// total `(i, j)` grid terms to spend across all non-exempt layers
    pub total_grid_terms: usize,
    /// §5.3 in-grid stop threshold copied into every non-exempt layer
    /// budget (`0.0` disables; see [`TermBudget::scale_floor`])
    pub scale_floor: f32,
}

impl BudgetPlanner {
    pub fn new(total_grid_terms: usize) -> BudgetPlanner {
        BudgetPlanner { total_grid_terms, scale_floor: 0.0 }
    }

    pub fn with_scale_floor(mut self, scale_floor: f32) -> BudgetPlanner {
        self.scale_floor = scale_floor;
        self
    }

    /// The exact grid cost of the uniform budget
    /// `TermBudget::new(w_cap, a_cap)` over `profiles`: every
    /// non-exempt layer at `min(w_cap, k) × min(a_cap, t)`. This is THE
    /// cost formula — `uniform_cost`, `floor_cost` and the controller's
    /// tier ceilings are all defined through it, so ceiling accounting
    /// can never desynchronize between planner and controller.
    pub fn grid_cost(profiles: &[LayerGridProfile], w_cap: usize, a_cap: usize) -> usize {
        profiles
            .iter()
            .filter(|p| !p.exempt)
            .map(|p| p.w_terms.min(w_cap).max(1) * p.a_terms.min(a_cap).max(1))
            .sum()
    }

    /// Grid cost of the PR 3-style uniform allocation with an
    /// unconstrained weight axis: every non-exempt layer capped at
    /// `a_cap` activation terms.
    pub fn uniform_cost(profiles: &[LayerGridProfile], a_cap: usize) -> usize {
        Self::grid_cost(profiles, usize::MAX, a_cap)
    }

    /// Minimum spend: every non-exempt layer at one activation term
    /// (the ≥ 1 floor of [`TermBudget`]).
    pub fn floor_cost(profiles: &[LayerGridProfile]) -> usize {
        Self::grid_cost(profiles, usize::MAX, 1)
    }

    /// Allocate the ceiling across `profiles` by marginal max-diff gain
    /// per grid-term cost. Exempt layers get a full budget; every other
    /// layer gets `TermBudget::new(w_terms, allocated_a)` (plus the
    /// plan's scale floor). The returned plan records the grid terms
    /// actually allocated as its total ceiling.
    pub fn plan(&self, profiles: &[LayerGridProfile]) -> BudgetPlan {
        let plannable: Vec<usize> = profiles
            .iter()
            .enumerate()
            .filter(|(_, p)| !p.exempt)
            .map(|(i, _)| i)
            .collect();
        // levels are activation term counts minus one: level 0 = the
        // 1-term floor (always affordable), level t-1 = the full axis
        let choice = greedy_allocate(
            plannable.len(),
            |i| profiles[plannable[i]].a_terms.max(1),
            |i, c| profiles[plannable[i]].gain(c),
            |i, _| profiles[plannable[i]].w_terms,
            |levels| {
                levels
                    .iter()
                    .zip(&plannable)
                    .map(|(&lv, &pi)| profiles[pi].w_terms * (lv + 1))
                    .sum()
            },
            self.total_grid_terms,
        );
        let mut layers = Vec::with_capacity(profiles.len());
        let mut allocated = 0usize;
        let mut next = 0usize;
        for p in profiles {
            if p.exempt {
                layers.push(TermBudget::full());
                continue;
            }
            let a = choice[next] + 1;
            next += 1;
            allocated += p.w_terms * a;
            let mut b = TermBudget::new(p.w_terms.max(1), a);
            if self.scale_floor > 0.0 {
                b = b.with_scale_floor(self.scale_floor);
            }
            layers.push(b);
        }
        BudgetPlan::per_layer(layers, TermBudget::full()).with_total_grid_terms(allocated)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Geometric convergence curve `first / ratio^t` — the Theorem 1
    /// shape every real layer series follows.
    fn geometric(first: f32, ratio: f32, terms: usize) -> Vec<f32> {
        (0..terms).map(|t| first / ratio.powi(t as i32)).collect()
    }

    fn profile(w_terms: usize, a_terms: usize, first: f32) -> LayerGridProfile {
        LayerGridProfile {
            w_terms,
            a_terms,
            exempt: false,
            max_diff: geometric(first, 16.0, a_terms),
        }
    }

    #[test]
    fn planner_shifts_terms_to_sensitive_layers() {
        // one slow-converging (large-activation) layer, one fast: at a
        // ceiling equal to the uniform 2-term cost, the sensitive layer
        // must get at least as many activation terms as the robust one
        let profiles = vec![profile(2, 4, 8.0), profile(2, 4, 0.01)];
        let ceiling = BudgetPlanner::uniform_cost(&profiles, 2);
        assert_eq!(ceiling, 8);
        let plan = BudgetPlanner::new(ceiling).plan(&profiles);
        let sensitive = plan.budget_for(0);
        let robust = plan.budget_for(1);
        assert!(
            sensitive.a_terms > robust.a_terms,
            "sensitive {sensitive} should outrank robust {robust}"
        );
        assert!(plan.total_grid_terms().unwrap() <= ceiling);
        assert_eq!(plan.layer_count(), 2);
    }

    #[test]
    fn exempt_layers_stay_full_and_uncharged() {
        let mut profiles = vec![profile(1, 1, 0.1), profile(2, 4, 1.0), profile(1, 1, 0.1)];
        profiles[0].exempt = true;
        profiles[2].exempt = true;
        assert_eq!(BudgetPlanner::floor_cost(&profiles), 2);
        let plan = BudgetPlanner::new(4).plan(&profiles);
        assert_eq!(plan.budget_for(0), TermBudget::full());
        assert_eq!(plan.budget_for(2), TermBudget::full());
        let mid = plan.budget_for(1);
        assert_eq!(mid.w_terms, 2);
        assert_eq!(mid.a_terms, 2, "ceiling 4 = 2 weight terms × 2 act terms");
        assert_eq!(plan.total_grid_terms(), Some(4));
    }

    #[test]
    fn ceiling_below_floor_still_gives_every_layer_one_term() {
        let profiles = vec![profile(2, 4, 1.0), profile(3, 4, 1.0)];
        let plan = BudgetPlanner::new(0).plan(&profiles);
        assert_eq!(plan.budget_for(0).a_terms, 1);
        assert_eq!(plan.budget_for(1).a_terms, 1);
        // the floor is spent even when the ceiling cannot afford it —
        // a zero-term layer forward is not a thing
        assert_eq!(plan.total_grid_terms(), Some(BudgetPlanner::floor_cost(&profiles)));
    }

    #[test]
    fn generous_ceiling_saturates_every_axis() {
        let profiles = vec![profile(2, 4, 1.0), profile(2, 3, 0.5)];
        let plan = BudgetPlanner::new(1000).plan(&profiles);
        assert_eq!(plan.budget_for(0).a_terms, 4);
        assert_eq!(plan.budget_for(1).a_terms, 3);
        assert_eq!(plan.total_grid_terms(), Some(2 * 4 + 2 * 3));
    }

    #[test]
    fn plans_nest_as_the_ceiling_grows() {
        let profiles = vec![profile(2, 4, 4.0), profile(2, 4, 0.5), profile(1, 4, 0.02)];
        let floor = BudgetPlanner::floor_cost(&profiles);
        let max = BudgetPlanner::uniform_cost(&profiles, 4);
        let mut prev: Option<BudgetPlan> = None;
        for ceiling in floor..=max {
            let plan = BudgetPlanner::new(ceiling).plan(&profiles);
            if let Some(p) = &prev {
                for i in 0..profiles.len() {
                    assert!(
                        p.budget_for(i).a_terms <= plan.budget_for(i).a_terms,
                        "layer {i} shrank when the ceiling grew to {ceiling}"
                    );
                }
                assert!(p.total_grid_terms() <= plan.total_grid_terms());
            }
            prev = Some(plan);
        }
    }

    #[test]
    fn scale_floor_is_carried_into_non_exempt_budgets() {
        let mut profiles = vec![profile(1, 1, 0.1), profile(2, 4, 1.0)];
        profiles[0].exempt = true;
        let plan = BudgetPlanner::new(8).with_scale_floor(1e-2).plan(&profiles);
        assert_eq!(plan.budget_for(0).scale_floor, 0.0, "exempt layers carry no stop");
        assert_eq!(plan.budget_for(1).scale_floor, 1e-2);
    }

    #[test]
    fn unprofiled_layers_stay_at_the_floor() {
        // no series → no measurable gain → the greedy loop never
        // upgrades past the 1-term floor, leaving ceiling for profiled
        // layers
        let profiles = vec![
            LayerGridProfile { w_terms: 2, a_terms: 4, exempt: false, max_diff: Vec::new() },
            profile(2, 4, 1.0),
        ];
        let plan = BudgetPlanner::new(10).plan(&profiles);
        assert_eq!(plan.budget_for(0).a_terms, 1);
        assert_eq!(plan.budget_for(1).a_terms, 4);
    }
}
