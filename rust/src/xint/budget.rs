//! Runtime term budgets — the paper's tensor/layer-granularity
//! truncation as a *serve-time* parameter.
//!
//! The seed stack fixed the Eq. 3 term grid at construction time: a
//! quantized layer always ran all `k·t` low-bit GEMMs. Because the
//! expansion is a *series* (geometric scale law, Theorem 1), any subset
//! of terms taken largest-scale-first is the best available
//! approximation at that compute cost — the same Abelian prefix
//! argument the QoS scheduler uses for pool-prefix truncation, applied
//! one level down inside a single layer's GEMM grid. A [`TermBudget`]
//! carries per-request caps on the weight/activation term axes (plus an
//! optional cap on the total `(i, j)` grid) through the whole forward
//! stack: `xint_linear_forward_budgeted` → `XintLinear::forward_with` →
//! `QuantModel::forward_with` → `QuantModelWorker::run_budgeted` →
//! `TermController::layer_budget_for`.

/// Per-request cap on the series terms a layer forward may spend.
///
/// Caps are upper bounds, clamped to what each layer actually has: a
/// budget of 3 activation terms leaves a 1-term 8-bit layer untouched.
/// Per-layer *policy resolution* happens in
/// [`LayerPolicy::resolve_budget`](super::layer::LayerPolicy::resolve_budget):
/// the §5.1 8-bit first/last layers are exempt and stay exact under any
/// request budget.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TermBudget {
    /// cap on weight expansion terms (the `i` axis of the Eq. 3 grid)
    pub w_terms: usize,
    /// cap on activation expansion terms (the `j` axis)
    pub a_terms: usize,
    /// optional cap on the total number of `(i, j)` INT GEMMs executed
    /// inside the `w_terms × a_terms` rectangle; pairs are taken in
    /// descending `s_wi · s_aj` order so any prefix is the best
    /// available approximation. `None` runs the whole rectangle.
    pub grid_terms: Option<usize>,
}

impl TermBudget {
    /// No truncation anywhere: the full `k·t` grid of every layer.
    pub const fn full() -> TermBudget {
        TermBudget { w_terms: usize::MAX, a_terms: usize::MAX, grid_terms: None }
    }

    /// Cap the weight/activation term axes (no separate grid cap).
    pub fn new(w_terms: usize, a_terms: usize) -> TermBudget {
        TermBudget { w_terms: w_terms.max(1), a_terms: a_terms.max(1), grid_terms: None }
    }

    /// Additionally cap the total `(i, j)` GEMM count.
    pub fn with_grid_terms(mut self, grid_terms: usize) -> TermBudget {
        self.grid_terms = Some(grid_terms.max(1));
        self
    }

    /// True iff this budget leaves a `k × t` grid untruncated — the
    /// forward then takes the legacy natural-order loop, so a full
    /// budget is bit-identical to the unbudgeted forward.
    pub fn covers(&self, k: usize, t: usize) -> bool {
        self.w_terms >= k
            && self.a_terms >= t
            && match self.grid_terms {
                None => true,
                Some(g) => g >= k * t,
            }
    }

    /// Effective caps against a concrete `k × t` grid (both ≥ 1).
    pub fn clamp_to(&self, k: usize, t: usize) -> (usize, usize) {
        (self.w_terms.clamp(1, k.max(1)), self.a_terms.clamp(1, t.max(1)))
    }
}

impl Default for TermBudget {
    fn default() -> TermBudget {
        TermBudget::full()
    }
}

impl std::fmt::Display for TermBudget {
    /// `full`, `2×4`, or `2×4/3` (axis caps plus a grid cap).
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        if *self == TermBudget::full() {
            return f.write_str("full");
        }
        match (self.w_terms, self.a_terms, self.grid_terms) {
            (w, a, None) => write!(f, "{w}×{a}"),
            (w, a, Some(g)) => write!(f, "{w}×{a}/{g}"),
        }
    }
}

/// What a budgeted forward actually spent — the observability half of
/// the budget contract (per-tier means surface in
/// [`Metrics`](crate::coordinator::Metrics)).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ForwardStats {
    /// `(i, j)` INT GEMM terms executed across all layers
    pub grid_terms: usize,
    /// expanded (conv/linear) layer forwards that contributed
    pub layers: usize,
}

impl ForwardStats {
    pub fn absorb(&mut self, other: ForwardStats) {
        self.grid_terms += other.grid_terms;
        self.layers += other.layers;
    }

    /// Record one layer forward that executed `grid_terms` GEMMs.
    pub fn record_layer(&mut self, grid_terms: usize) {
        self.grid_terms += grid_terms;
        self.layers += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn full_budget_covers_everything() {
        let b = TermBudget::full();
        assert!(b.covers(3, 7));
        assert_eq!(b.clamp_to(2, 4), (2, 4));
        assert_eq!(TermBudget::default(), b);
    }

    #[test]
    fn caps_clamp_to_the_grid() {
        let b = TermBudget::new(1, 2);
        assert!(!b.covers(2, 4));
        assert_eq!(b.clamp_to(2, 4), (1, 2));
        // caps never exceed what the layer has, never fall below 1
        assert_eq!(TermBudget::new(9, 9).clamp_to(2, 4), (2, 4));
        assert_eq!(TermBudget::new(0, 0).clamp_to(2, 4), (1, 1));
    }

    #[test]
    fn grid_cap_breaks_coverage() {
        let b = TermBudget::new(2, 4).with_grid_terms(3);
        assert!(!b.covers(2, 4));
        assert!(TermBudget::new(2, 4).with_grid_terms(8).covers(2, 4));
        assert!(TermBudget::new(2, 4).covers(2, 4));
    }

    #[test]
    fn display_labels() {
        assert_eq!(TermBudget::full().to_string(), "full");
        assert_eq!(TermBudget::new(2, 4).to_string(), "2×4");
        assert_eq!(TermBudget::new(2, 4).with_grid_terms(3).to_string(), "2×4/3");
    }

    #[test]
    fn stats_accumulate() {
        let mut s = ForwardStats::default();
        s.record_layer(8);
        s.record_layer(1);
        let mut total = ForwardStats::default();
        total.absorb(s);
        total.absorb(ForwardStats { grid_terms: 2, layers: 1 });
        assert_eq!(total, ForwardStats { grid_terms: 11, layers: 3 });
    }
}
