//! Runtime budget hierarchy — the paper's *tensor*-granularity
//! truncation (§4, Theorem 1 converges per tensor) as a serve-time
//! parameter, planned per layer.
//!
//! Two levels:
//!
//! * [`TermBudget`] — the per-layer **leaf**: caps on one layer's Eq. 3
//!   grid (weight/activation term axes, an optional total `(i, j)` cap,
//!   and the §5.3 in-grid stop threshold [`TermBudget::scale_floor`]).
//!   Because the expansion is a *series* (geometric scale law), any
//!   subset of grid pairs taken largest-scale-first is the best
//!   available approximation at that compute cost — the same Abelian
//!   prefix argument the QoS scheduler uses for pool-prefix truncation,
//!   applied inside a single layer's GEMM grid.
//! * [`BudgetPlan`] — the unit that flows through the forward stack: a
//!   per-layer vector of `TermBudget`s (indexed by quantizable-layer
//!   position, depth-first) plus the global grid-term ceiling the plan
//!   was allocated under. Layers converge at different rates, so a
//!   uniform cap overspends on robust layers and starves sensitive
//!   ones; the [`BudgetPlanner`](super::planner::BudgetPlanner)
//!   allocates a tier's total ceiling across layers by marginal
//!   max-diff gain. [`BudgetPlan::uniform`] reproduces the pre-plan
//!   behavior (one scalar budget for every layer), and
//!   [`BudgetPlan::full`] is bit-identical to the unbudgeted forward.
//!
//! The plan flows `TermController::plan_for` →
//! `ExpansionScheduler::process` → `BasisWorker::run_budgeted` →
//! `QuantModel::forward_with` (which indexes the plan by layer
//! position) → `LayerPolicy::resolve_budget` →
//! `xint_linear_forward_budgeted` (which consumes the per-layer leaf).

/// Per-layer cap on the series terms a single layer forward may spend.
///
/// Caps are upper bounds, clamped to what each layer actually has: a
/// budget of 3 activation terms leaves a 1-term 8-bit layer untouched.
/// Every cap has a **floor of 1**: a layer forward always executes at
/// least one term per axis (a zero-term forward would output garbage,
/// not a coarser approximation), so the constructors lift zero caps to
/// 1 — and debug-assert, because a zero cap is a caller bug, not a
/// request for the floor. Per-layer *policy resolution* happens in
/// [`LayerPolicy::resolve_budget`](super::layer::LayerPolicy::resolve_budget):
/// the §5.1 8-bit first/last layers are exempt and stay exact under any
/// request budget.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct TermBudget {
    /// cap on weight expansion terms (the `i` axis of the Eq. 3 grid)
    pub w_terms: usize,
    /// cap on activation expansion terms (the `j` axis)
    pub a_terms: usize,
    /// optional cap on the total number of `(i, j)` INT GEMMs executed
    /// inside the `w_terms × a_terms` rectangle; pairs are taken in
    /// descending `s_wi · s_aj` order so any prefix is the best
    /// available approximation. `None` runs the whole rectangle.
    pub grid_terms: Option<usize>,
    /// §5.3 in-grid anytime stop: the sorted `(i, j)` execution stops
    /// once a pair's scale product `s_wi · s_aj` falls below
    /// `scale_floor ×` the layer's *leading* (largest) product — a
    /// relative, scale-invariant threshold, the same design as the
    /// pool-prefix anytime stop (the auto-stop rule applied *inside*
    /// one layer's grid instead of as a fixed cap). The leading pair
    /// always executes (the ≥ 1 floor). `0.0` disables the stop; any
    /// positive floor routes the forward through the sorted path even
    /// when the axis caps cover the grid.
    pub scale_floor: f32,
}

impl TermBudget {
    /// No truncation anywhere: the full `k·t` grid of every layer.
    pub const fn full() -> TermBudget {
        TermBudget {
            w_terms: usize::MAX,
            a_terms: usize::MAX,
            grid_terms: None,
            scale_floor: 0.0,
        }
    }

    /// Cap the weight/activation term axes (no separate grid cap).
    /// Zero caps are a caller bug (debug-asserted) and lift to the
    /// documented ≥ 1 floor in release builds.
    pub fn new(w_terms: usize, a_terms: usize) -> TermBudget {
        debug_assert!(
            w_terms >= 1 && a_terms >= 1,
            "TermBudget caps must be >= 1 (got {w_terms}×{a_terms}); \
             a zero-term forward is not a coarser approximation"
        );
        TermBudget {
            w_terms: w_terms.max(1),
            a_terms: a_terms.max(1),
            grid_terms: None,
            scale_floor: 0.0,
        }
    }

    /// Additionally cap the total `(i, j)` GEMM count (≥ 1 floor, as
    /// [`TermBudget::new`]).
    pub fn with_grid_terms(mut self, grid_terms: usize) -> TermBudget {
        debug_assert!(grid_terms >= 1, "grid cap must be >= 1 (got {grid_terms})");
        self.grid_terms = Some(grid_terms.max(1));
        self
    }

    /// Set the §5.3 in-grid stop threshold on the scale product.
    pub fn with_scale_floor(mut self, scale_floor: f32) -> TermBudget {
        debug_assert!(
            scale_floor >= 0.0 && scale_floor.is_finite(),
            "scale floor must be finite and >= 0 (got {scale_floor})"
        );
        self.scale_floor = scale_floor;
        self
    }

    /// True iff this budget leaves a `k × t` grid untruncated — the
    /// forward then takes the legacy natural-order loop, so a full
    /// budget is bit-identical to the unbudgeted forward. A positive
    /// [`scale_floor`](TermBudget::scale_floor) never covers: the §5.3
    /// stop needs the sorted largest-first order to be a prefix rule.
    pub fn covers(&self, k: usize, t: usize) -> bool {
        self.scale_floor == 0.0
            && self.w_terms >= k
            && self.a_terms >= t
            && match self.grid_terms {
                None => true,
                Some(g) => g >= k * t,
            }
    }

    /// Effective caps against a concrete `k × t` grid (both ≥ 1).
    pub fn clamp_to(&self, k: usize, t: usize) -> (usize, usize) {
        (self.w_terms.clamp(1, k.max(1)), self.a_terms.clamp(1, t.max(1)))
    }
}

impl Default for TermBudget {
    fn default() -> TermBudget {
        TermBudget::full()
    }
}

impl std::fmt::Display for TermBudget {
    /// `full`, `2×4`, `2×4/3` (axis caps plus a grid cap), with a
    /// `@1e-2`-style suffix when a §5.3 scale floor is set.
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        if *self == TermBudget::full() {
            return f.write_str("full");
        }
        match (self.w_terms, self.a_terms, self.grid_terms) {
            (w, a, None) => write!(f, "{w}×{a}")?,
            (w, a, Some(g)) => write!(f, "{w}×{a}/{g}")?,
        }
        if self.scale_floor > 0.0 {
            write!(f, "@{:.0e}", self.scale_floor)?;
        }
        Ok(())
    }
}

/// The unit that flows through the forward stack: one [`TermBudget`]
/// per quantizable layer (depth-first position, matching
/// `quantize_model`'s traversal) plus the global grid-term ceiling the
/// allocation was made under.
///
/// Positions beyond the per-layer vector fall back to the uniform
/// budget — so [`BudgetPlan::uniform`] (empty vector) reproduces the
/// pre-plan behavior of one scalar budget for every layer, and a plan
/// built for one model applied to a deeper one degrades safely to its
/// fallback instead of panicking.
#[derive(Clone, Debug, PartialEq)]
pub struct BudgetPlan {
    /// per-layer budgets by quantizable-layer position
    layers: Vec<TermBudget>,
    /// budget for positions without a per-layer entry
    fallback: TermBudget,
    /// total `(i, j)` grid terms the planner allocated across the
    /// non-exempt layers (`None` for uniform/full plans, which carry
    /// no ceiling)
    total_grid_terms: Option<usize>,
}

impl BudgetPlan {
    /// Every layer untruncated — bit-identical to the unbudgeted
    /// forward (the Exact tier's plan).
    pub fn full() -> BudgetPlan {
        BudgetPlan::uniform(TermBudget::full())
    }

    /// One scalar budget for every layer — PR 3's behavior as a plan.
    pub fn uniform(budget: TermBudget) -> BudgetPlan {
        BudgetPlan { layers: Vec::new(), fallback: budget, total_grid_terms: None }
    }

    /// A sensitivity-allocated plan: `layers[i]` caps quantizable layer
    /// `i`; positions past the vector take `fallback`.
    pub fn per_layer(layers: Vec<TermBudget>, fallback: TermBudget) -> BudgetPlan {
        BudgetPlan { layers, fallback, total_grid_terms: None }
    }

    /// Record the global grid-term ceiling this plan was allocated
    /// under (observability + pressure replanning).
    pub fn with_total_grid_terms(mut self, total: usize) -> BudgetPlan {
        self.total_grid_terms = Some(total);
        self
    }

    /// The budget for quantizable layer `layer` (depth-first position).
    pub fn budget_for(&self, layer: usize) -> TermBudget {
        self.layers.get(layer).copied().unwrap_or(self.fallback)
    }

    /// Number of per-layer entries (0 for uniform plans).
    pub fn layer_count(&self) -> usize {
        self.layers.len()
    }

    /// True when the plan has no per-layer entries (every layer takes
    /// the fallback budget).
    pub fn is_uniform(&self) -> bool {
        self.layers.is_empty()
    }

    /// True when every layer runs untruncated (the Exact contract).
    pub fn is_full(&self) -> bool {
        self.fallback == TermBudget::full() && self.layers.iter().all(|b| *b == TermBudget::full())
    }

    /// The global grid-term ceiling, when the plan carries one.
    pub fn total_grid_terms(&self) -> Option<usize> {
        self.total_grid_terms
    }
}

impl Default for BudgetPlan {
    fn default() -> BudgetPlan {
        BudgetPlan::full()
    }
}

impl std::fmt::Display for BudgetPlan {
    /// `uniform(full)`, `uniform(2×4)`, or `plan(5 layers, 24 grid)`.
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        if self.is_uniform() {
            return write!(f, "uniform({})", self.fallback);
        }
        match self.total_grid_terms {
            Some(t) => write!(f, "plan({} layers, {t} grid)", self.layers.len()),
            None => write!(f, "plan({} layers)", self.layers.len()),
        }
    }
}

/// What a budgeted forward actually spent — the observability half of
/// the budget contract (per-tier means surface in
/// [`Metrics`](crate::coordinator::Metrics)).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ForwardStats {
    /// `(i, j)` INT GEMM terms executed across all layers
    pub grid_terms: usize,
    /// expanded (conv/linear) layer forwards that contributed
    pub layers: usize,
}

impl ForwardStats {
    pub fn absorb(&mut self, other: ForwardStats) {
        self.grid_terms += other.grid_terms;
        self.layers += other.layers;
    }

    /// Record one layer forward that executed `grid_terms` GEMMs.
    pub fn record_layer(&mut self, grid_terms: usize) {
        self.grid_terms += grid_terms;
        self.layers += 1;
    }
}

/// Per-layer record from a traced budgeted forward
/// (`QuantModel::forward_traced`): what one layer's Eq. 3 grid actually
/// executed vs what its resolved plan entry allowed, with nanosecond
/// offsets from the traced forward's start so the trace plane can place
/// each layer inside its worker span. The §5.3 stop depth is
/// `grid_terms` out of `planned_grid` ([`LayerTrace::floor_stopped`]).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct LayerTrace {
    /// depth-first quantizable-layer position (the plan index)
    pub index: usize,
    /// `(i, j)` INT GEMMs this layer executed
    pub grid_terms: usize,
    /// GEMMs the resolved budget permitted (§5.1-exempt and FP-fallback
    /// layers ignore the plan, so they report `planned_grid ==
    /// grid_terms`)
    pub planned_grid: usize,
    /// ns offsets from the traced forward's start
    pub t_start_ns: u64,
    pub t_end_ns: u64,
}

impl LayerTrace {
    /// True when the §5.3 in-grid scale floor stopped the sorted grid
    /// walk before the planned cap.
    pub fn floor_stopped(&self) -> bool {
        self.grid_terms < self.planned_grid
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn full_budget_covers_everything() {
        let b = TermBudget::full();
        assert!(b.covers(3, 7));
        assert_eq!(b.clamp_to(2, 4), (2, 4));
        assert_eq!(TermBudget::default(), b);
    }

    #[test]
    fn caps_clamp_to_the_grid() {
        let b = TermBudget::new(1, 2);
        assert!(!b.covers(2, 4));
        assert_eq!(b.clamp_to(2, 4), (1, 2));
        // caps never exceed what the layer has
        assert_eq!(TermBudget::new(9, 9).clamp_to(2, 4), (2, 4));
    }

    #[test]
    #[cfg(debug_assertions)]
    #[should_panic(expected = "caps must be >= 1")]
    fn zero_caps_are_a_caller_bug() {
        let _ = TermBudget::new(0, 0);
    }

    #[test]
    #[cfg(debug_assertions)]
    #[should_panic(expected = "grid cap must be >= 1")]
    fn zero_grid_cap_is_a_caller_bug() {
        let _ = TermBudget::new(1, 1).with_grid_terms(0);
    }

    #[test]
    fn grid_cap_breaks_coverage() {
        let b = TermBudget::new(2, 4).with_grid_terms(3);
        assert!(!b.covers(2, 4));
        assert!(TermBudget::new(2, 4).with_grid_terms(8).covers(2, 4));
        assert!(TermBudget::new(2, 4).covers(2, 4));
    }

    #[test]
    fn scale_floor_breaks_coverage() {
        // a positive §5.3 floor must route through the sorted path even
        // when the axis caps cover the grid
        let b = TermBudget::new(2, 4).with_scale_floor(1e-3);
        assert!(!b.covers(2, 4));
        assert!(TermBudget::new(2, 4).with_scale_floor(0.0).covers(2, 4));
    }

    #[test]
    fn display_labels() {
        assert_eq!(TermBudget::full().to_string(), "full");
        assert_eq!(TermBudget::new(2, 4).to_string(), "2×4");
        assert_eq!(TermBudget::new(2, 4).with_grid_terms(3).to_string(), "2×4/3");
        assert_eq!(TermBudget::new(2, 4).with_scale_floor(1e-2).to_string(), "2×4@1e-2");
    }

    #[test]
    fn stats_accumulate() {
        let mut s = ForwardStats::default();
        s.record_layer(8);
        s.record_layer(1);
        let mut total = ForwardStats::default();
        total.absorb(s);
        total.absorb(ForwardStats { grid_terms: 2, layers: 1 });
        assert_eq!(total, ForwardStats { grid_terms: 11, layers: 3 });
    }

    #[test]
    fn uniform_plan_applies_one_budget_everywhere() {
        let b = TermBudget::new(2, 3);
        let plan = BudgetPlan::uniform(b);
        assert!(plan.is_uniform());
        assert!(!plan.is_full());
        assert_eq!(plan.layer_count(), 0);
        assert_eq!(plan.budget_for(0), b);
        assert_eq!(plan.budget_for(99), b);
        assert_eq!(plan.total_grid_terms(), None);
        assert!(BudgetPlan::full().is_full());
        assert_eq!(BudgetPlan::default(), BudgetPlan::full());
    }

    #[test]
    fn per_layer_plan_indexes_by_position_with_fallback() {
        let plan = BudgetPlan::per_layer(
            vec![TermBudget::full(), TermBudget::new(2, 1), TermBudget::new(2, 3)],
            TermBudget::full(),
        )
        .with_total_grid_terms(8);
        assert!(!plan.is_uniform());
        assert_eq!(plan.layer_count(), 3);
        assert_eq!(plan.budget_for(0), TermBudget::full());
        assert_eq!(plan.budget_for(1), TermBudget::new(2, 1));
        assert_eq!(plan.budget_for(2), TermBudget::new(2, 3));
        // past the vector: safe fallback, not a panic
        assert_eq!(plan.budget_for(3), TermBudget::full());
        assert_eq!(plan.total_grid_terms(), Some(8));
        assert!(!plan.is_full(), "a truncating entry breaks fullness");
    }

    #[test]
    fn plan_display_labels() {
        assert_eq!(BudgetPlan::full().to_string(), "uniform(full)");
        assert_eq!(BudgetPlan::uniform(TermBudget::new(2, 4)).to_string(), "uniform(2×4)");
        let p = BudgetPlan::per_layer(vec![TermBudget::new(2, 1); 5], TermBudget::full())
            .with_total_grid_terms(24);
        assert_eq!(p.to_string(), "plan(5 layers, 24 grid)");
    }
}
