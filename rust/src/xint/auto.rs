//! Automatic term-count resolution — the paper's two stopping rules as a
//! policy resolver:
//!
//! * weights (§4, "The Weight Expansion Upper Bound"): grow `k` until the
//!   total-differential criterion `scale_k · 2^X < 1e-2` holds (trained
//!   weights have zero loss-gradient, so finer weight terms are invisible
//!   to the loss) — in practice k = 2–3.
//! * activations (§5.3): grow `t` until the max reconstruction residual
//!   on a probe batch drops below `1e-4` — in practice t ≈ 4 at INT4.

use super::expansion::ExpandConfig;
use super::layer::{weight_term_bound, LayerPolicy};
use super::monitor::ExpansionMonitor;
use super::BitSpec;
use crate::tensor::Tensor;

/// Tunable thresholds (paper defaults).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct AutoConfig {
    /// §4: `scale_k · 2^X < w_threshold` stops the weight expansion
    pub w_threshold: f32,
    /// §5.3: max activation residual < a_tol stops the act expansion
    pub a_tol: f32,
    pub max_w_terms: usize,
    pub max_a_terms: usize,
}

impl Default for AutoConfig {
    fn default() -> Self {
        AutoConfig { w_threshold: 1e-2, a_tol: 1e-4, max_w_terms: 3, max_a_terms: 6 }
    }
}

/// Resolve a [`LayerPolicy`] for one layer from its weight tensor and a
/// probe activation batch.
pub fn resolve_policy(
    w: &Tensor,
    probe_act: &Tensor,
    w_bits: u32,
    a_bits: u32,
    cfg: &AutoConfig,
) -> LayerPolicy {
    let k = weight_term_bound(w, BitSpec::int(w_bits), cfg.w_threshold, cfg.max_w_terms);
    let mut mon = ExpansionMonitor::new();
    mon.observe(probe_act, &ExpandConfig::activations(BitSpec::int(a_bits), cfg.max_a_terms))
        .expect("fresh monitor accepts its first config");
    let t = mon.optimal_terms(cfg.a_tol).unwrap_or(cfg.max_a_terms);
    LayerPolicy::new(w_bits, a_bits).with_terms(k, t)
}

/// Auto-quantize a model: resolve one global activation term count from a
/// probe batch, per the §5.3 rule, and the weight bound from the largest
/// weight scale in the model (conservative: the §4 criterion must hold
/// for every layer).
pub fn quantize_model_auto(
    model: &crate::models::Model,
    probe: &Tensor,
    w_bits: u32,
    a_bits: u32,
    cfg: &AutoConfig,
) -> (crate::models::quantized::QuantModel, LayerPolicy) {
    // weight bound from the max-|w| layer
    let mut folded = model.clone();
    folded.fold_bn();
    let mut max_scale_w: Option<Tensor> = None;
    visit_weights(&folded.layers, &mut |w| {
        let cur = max_scale_w.as_ref().map(|t| t.max_abs()).unwrap_or(0.0);
        if w.max_abs() > cur {
            max_scale_w = Some(w.clone());
        }
    });
    let wref = max_scale_w.expect("model has no quantizable layers");
    let policy = resolve_policy(&wref, probe, w_bits, a_bits, cfg);
    (crate::models::quantized::quantize_model(model, policy), policy)
}

fn visit_weights(layers: &[crate::models::Layer], f: &mut dyn FnMut(&Tensor)) {
    use crate::models::Layer;
    for l in layers {
        match l {
            Layer::Conv(c) => f(&c.w),
            Layer::Linear(lin) => f(&lin.w),
            Layer::Residual(m, s) => {
                visit_weights(m, f);
                visit_weights(s, f);
            }
            Layer::Branches(bs) => {
                for b in bs {
                    visit_weights(b, f);
                }
            }
            _ => {}
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Rng;

    #[test]
    fn resolve_policy_matches_paper_defaults_at_int4() {
        let mut rng = Rng::seed(401);
        // trained-scale weights (max ≈ 0.5) and unit-scale activations
        let w = Tensor::randn(&[16, 32], 0.15, &mut rng);
        let a = Tensor::randn(&[8, 32], 1.0, &mut rng);
        let p = resolve_policy(&w, &a, 4, 4, &AutoConfig::default());
        assert!(
            (2..=3).contains(&p.w_terms),
            "weight terms {} outside the paper's 2–3",
            p.w_terms
        );
        assert!(
            (3..=5).contains(&p.a_terms),
            "act terms {} outside the paper's ≈4",
            p.a_terms
        );
    }

    #[test]
    fn more_bits_need_fewer_terms() {
        let mut rng = Rng::seed(402);
        let w = Tensor::randn(&[16, 32], 0.15, &mut rng);
        let a = Tensor::randn(&[8, 32], 1.0, &mut rng);
        let p4 = resolve_policy(&w, &a, 4, 4, &AutoConfig::default());
        let p8 = resolve_policy(&w, &a, 8, 8, &AutoConfig::default());
        assert!(p8.w_terms <= p4.w_terms);
        assert!(p8.a_terms <= p4.a_terms);
    }

    #[test]
    fn auto_quantize_model_end_to_end() {
        let data = crate::datasets::SynthImg::new(4, 1, 12, 0.2, 403);
        let mut m = crate::models::zoo::mini_resnet_a(4, 404);
        let cfg = crate::train::TrainConfig { steps: 60, batch: 16, lr: 0.05, log_every: 1000 };
        crate::train::train_classifier(&mut m, &data, &cfg);
        let probe = data.batch(16, 3).x;
        let (q, policy) = quantize_model_auto(&m, &probe, 4, 4, &AutoConfig::default());
        assert!(policy.w_terms >= 2);
        assert!(policy.a_terms >= 2);
        let val = data.batch(128, 2);
        let acc = crate::datasets::accuracy(&q.forward(&val.x), &val.y);
        let mut fp = m.clone();
        fp.fold_bn();
        let fp_acc = crate::datasets::accuracy(&fp.forward(&val.x), &val.y);
        assert!(acc >= fp_acc - 0.05, "auto W4A4 {acc:.3} vs FP {fp_acc:.3}");
    }
}
