//! AbelianAdd (⊎) and AbelianMul (∗) — §3.3.
//!
//! The paper defines ⊎ on isomorphic models by summing homologous
//! parameters/outputs (Eqs. 5–6), and ∗ as a per-layer scale vector
//! applied to weights (Definition 2). `(basis models, ⊎)` forms an
//! Abelian group, which is exactly the algebra AllReduce needs: the
//! reduction is associative + commutative, so the coordinator may reduce
//! basis outputs in any tree order ([`abelian_reduce`]).
//!
//! [`LinearModel`] is a minimal isomorphic-model type on which the group
//! laws are *provable* and property-tested (identity, inverse,
//! commutativity, associativity, and the Eq. 5/6 homomorphisms). The real
//! CNN/transformer basis models reuse only the output-side reduction,
//! which is what Theorem 2's AllReduce needs.

use crate::tensor::Tensor;

/// A stack of linear layers `y = W_L ⋯ W_1 x` — the isomorphic-model
/// class on which AbelianAdd/Mul are exact.
#[derive(Clone, Debug, PartialEq)]
pub struct LinearModel {
    pub weights: Vec<Tensor>,
}

impl LinearModel {
    pub fn new(weights: Vec<Tensor>) -> Self {
        for w in weights.windows(2) {
            assert_eq!(w[1].dims()[1], w[0].dims()[0], "layer dims must chain");
        }
        LinearModel { weights }
    }

    /// Isomorphic zero model (the ⊎ identity).
    pub fn zero_like(&self) -> LinearModel {
        LinearModel { weights: self.weights.iter().map(|w| Tensor::zeros(w.dims())).collect() }
    }

    /// Isomorphic negation (the ⊎ inverse).
    pub fn neg(&self) -> LinearModel {
        LinearModel { weights: self.weights.iter().map(|w| w.scale(-1.0)).collect() }
    }

    /// AbelianAdd ⊎: parameter-wise sum of isomorphic models (Eq. 5).
    pub fn abelian_add(&self, other: &LinearModel) -> LinearModel {
        assert_eq!(self.weights.len(), other.weights.len(), "models must be isomorphic");
        LinearModel {
            weights: self
                .weights
                .iter()
                .zip(&other.weights)
                .map(|(a, b)| a.add(b))
                .collect(),
        }
    }

    /// Forward pass `Model(W, x)`.
    pub fn forward(&self, x: &Tensor) -> Tensor {
        let mut h = x.clone();
        for w in &self.weights {
            h = crate::tensor::matmul_a_bt(&h, w);
        }
        h
    }
}

/// AbelianMul ∗ (Definition 2): a per-layer scale vector `U` applied to
/// the model's parameters, `U ∗ model(W_i) = model(u_i · W_i)`.
#[derive(Clone, Debug, PartialEq)]
pub struct AbelianMul {
    pub u: Vec<f32>,
}

impl AbelianMul {
    pub fn new(u: Vec<f32>) -> Self {
        AbelianMul { u }
    }

    pub fn identity(layers: usize) -> Self {
        AbelianMul { u: vec![1.0; layers] }
    }

    /// Apply to a linear model.
    pub fn apply(&self, m: &LinearModel) -> LinearModel {
        assert_eq!(self.u.len(), m.weights.len(), "scale vector arity");
        LinearModel {
            weights: m
                .weights
                .iter()
                .zip(&self.u)
                .map(|(w, &u)| w.scale(u))
                .collect(),
        }
    }

    /// Compose two scale vectors (the group op of the multiplicative side).
    pub fn compose(&self, other: &AbelianMul) -> AbelianMul {
        assert_eq!(self.u.len(), other.u.len());
        AbelianMul { u: self.u.iter().zip(&other.u).map(|(a, b)| a * b).collect() }
    }

    /// Effective scalar on the model *output* for a linear model: Π u_i.
    pub fn output_gain(&self) -> f32 {
        self.u.iter().product()
    }
}

/// The AllReduce reduction of basis-model outputs under ⊎ (output side):
/// pairwise tree sum. Because ⊎ is an Abelian group op, any tree order
/// gives the same result — the property the coordinator's parallel
/// reduction relies on (and that `tests::reduce_order_invariant` checks).
pub fn abelian_reduce(mut outputs: Vec<Tensor>) -> Option<Tensor> {
    if outputs.is_empty() {
        return None;
    }
    // balanced binary tree, mirroring a log-depth AllReduce
    while outputs.len() > 1 {
        let mut next = Vec::with_capacity(outputs.len().div_ceil(2));
        let mut it = outputs.into_iter();
        while let Some(a) = it.next() {
            match it.next() {
                Some(b) => next.push(a.add(&b)),
                None => next.push(a),
            }
        }
        outputs = next;
    }
    outputs.pop()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Rng;

    fn rand_model(dims: &[usize], seed: u64) -> LinearModel {
        let mut rng = Rng::seed(seed);
        let weights = dims
            .windows(2)
            .map(|w| Tensor::randn(&[w[1], w[0]], 0.5, &mut rng))
            .collect();
        LinearModel::new(weights)
    }

    fn close(a: &Tensor, b: &Tensor, tol: f32) {
        assert_eq!(a.dims(), b.dims());
        for (x, y) in a.data().iter().zip(b.data()) {
            assert!((x - y).abs() <= tol * (1.0 + x.abs().max(y.abs())), "{x} vs {y}");
        }
    }

    #[test]
    fn group_laws_hold() {
        let a = rand_model(&[4, 6, 3], 1);
        let b = rand_model(&[4, 6, 3], 2);
        let c = rand_model(&[4, 6, 3], 3);
        // commutativity (exact in IEEE: x+y == y+x)
        assert_eq!(a.abelian_add(&b), b.abelian_add(&a));
        // associativity (holds up to f32 rounding)
        let lhs = a.abelian_add(&b).abelian_add(&c);
        let rhs = a.abelian_add(&b.abelian_add(&c));
        for (wl, wr) in lhs.weights.iter().zip(&rhs.weights) {
            close(wl, wr, 1e-6);
        }
        // identity
        assert_eq!(a.abelian_add(&a.zero_like()), a);
        // inverse
        assert_eq!(a.abelian_add(&a.neg()), a.zero_like());
    }

    #[test]
    fn eq5_weight_additivity_single_layer() {
        // Model(W1,A,x) ⊎ Model(W2,A,x) == Model(W1+W2,A,x) — exact for
        // a single linear layer (output-side ⊎ = output sum)
        let mut rng = Rng::seed(4);
        let w1 = Tensor::randn(&[5, 8], 1.0, &mut rng);
        let w2 = Tensor::randn(&[5, 8], 1.0, &mut rng);
        let x = Tensor::randn(&[3, 8], 1.0, &mut rng);
        let m1 = LinearModel::new(vec![w1.clone()]);
        let m2 = LinearModel::new(vec![w2.clone()]);
        let sum = LinearModel::new(vec![w1.add(&w2)]);
        let lhs = m1.forward(&x).add(&m2.forward(&x));
        close(&lhs, &sum.forward(&x), 1e-5);
    }

    #[test]
    fn eq6_activation_additivity() {
        // Model(W,A1) ⊎ Model(W,A2) == Model(W,A1+A2) for linear layers
        let mut rng = Rng::seed(5);
        let m = rand_model(&[8, 5, 4], 6);
        let x1 = Tensor::randn(&[2, 8], 1.0, &mut rng);
        let x2 = Tensor::randn(&[2, 8], 1.0, &mut rng);
        let lhs = m.forward(&x1).add(&m.forward(&x2));
        close(&lhs, &m.forward(&x1.add(&x2)), 1e-4);
    }

    #[test]
    fn abelian_mul_is_weight_scaling() {
        let m = rand_model(&[6, 4, 2], 7);
        let mut rng = Rng::seed(8);
        let x = Tensor::randn(&[3, 6], 1.0, &mut rng);
        let u = AbelianMul::new(vec![2.0, -0.5]);
        let lhs = u.apply(&m).forward(&x);
        // for linear models: output scales by Π u_i
        let rhs = m.forward(&x).scale(u.output_gain());
        close(&lhs, &rhs, 1e-4);
    }

    #[test]
    fn abelian_mul_composition() {
        let m = rand_model(&[4, 4], 9);
        let u1 = AbelianMul::new(vec![3.0]);
        let u2 = AbelianMul::new(vec![0.25]);
        assert_eq!(u1.apply(&u2.apply(&m)), u1.compose(&u2).apply(&m));
        assert_eq!(AbelianMul::identity(1).apply(&m), m);
    }

    #[test]
    fn reduce_order_invariant() {
        let mut rng = Rng::seed(10);
        let outs: Vec<Tensor> =
            (0..7).map(|_| Tensor::randn(&[2, 3], 1.0, &mut rng)).collect();
        let tree = abelian_reduce(outs.clone()).unwrap();
        // sequential left fold
        let mut seq = Tensor::zeros(&[2, 3]);
        for o in &outs {
            seq = seq.add(o);
        }
        close(&tree, &seq, 1e-5);
        // random permutation
        let mut perm = outs.clone();
        rng.shuffle(&mut perm);
        close(&tree, &abelian_reduce(perm).unwrap(), 1e-5);
    }

    #[test]
    fn reduce_empty_is_none_single_is_identity() {
        assert!(abelian_reduce(vec![]).is_none());
        let t = Tensor::vec1(&[1.0, 2.0]);
        assert_eq!(abelian_reduce(vec![t.clone()]).unwrap(), t);
    }

    #[test]
    fn property_group_laws_random() {
        use crate::util::prop::{forall, no_shrink, PropConfig};
        forall(
            PropConfig { cases: 24, seed: 0xBEEF, max_shrink: 0 },
            |r| {
                let d1 = 1 + r.below(5);
                let d2 = 1 + r.below(5);
                let mut rng = r.fork(2);
                (
                    rand_model(&[d1, d2], rng.next_u64()),
                    rand_model(&[d1, d2], rng.next_u64()),
                )
            },
            no_shrink,
            |(a, b)| {
                if a.abelian_add(b) != b.abelian_add(a) {
                    return Err("commutativity".into());
                }
                if a.abelian_add(&a.zero_like()) != *a {
                    return Err("identity".into());
                }
                Ok(())
            },
        );
    }
}
