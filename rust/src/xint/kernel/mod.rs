//! Packed SIMD + row-parallel INT GEMM kernels for the Eq. 3 grid.
//!
//! The serving hot path executes `k·t` integer GEMMs per layer (the
//! red grid of Figure 2). This module is the INT8-unit stand-in the
//! paper assumes on its A800, built in three layers:
//!
//! * [`pack`] — basis planes narrowed to row-major `i8` once (weights
//!   at load, activations once per layer call) and reused across every
//!   grid cell, with per-row sums as metadata for the rank-1 `bias_w`
//!   path.
//! * [`micro`] — the inner dot: AVX2 `maddubs` widening (32 MACs per
//!   instruction) behind runtime feature detection, with a portable
//!   scalar-unrolled fallback (`FP_XINT_FORCE_PORTABLE=1` forces it).
//! * [`parallel`] — a persistent worker set splitting output-row
//!   blocks across lanes via a single `fetch_add` claim cursor
//!   (protocol pinned by `loom_model_kernel_block_claim_exactly_once`).
//!
//! Everything is exact integer arithmetic and the float scale is
//! applied with the same expression in the same per-element pair order
//! as the scalar `int_gemm_scaled_into`, so all three tiers — scalar,
//! portable, AVX2, sequential or row-parallel — produce bit-identical
//! output. `xint::gemm` falls back to the scalar kernel whenever a
//! plane exceeds the [`PACK_MAX_ABS`] i8 envelope.

pub mod micro;
pub mod pack;
pub mod parallel;

pub use micro::{active_kernel, dot4_i8, dot_i8, dot_i8_portable, Kernel};
pub use pack::{PackedPlane, PACK_MAX_ABS};
pub use parallel::{execute_parallel_with, set_interop_workers, shared, KernelPool};

use crate::util::sync::Arc;

/// Column-block width of the cache-blocked executor: 64 weight rows of
/// `k ≤ 4096` i8 values stay L2-resident while an activation row
/// streams across them.
const NC: usize = 64;

/// Grids below this many MACs run sequentially — the parallel dispatch
/// round-trip (~tens of µs) needs real work to amortize.
const PAR_MIN_MACS: usize = 1 << 22;

/// One layer call's resolved Eq. 3 grid over packed planes: the
/// `(wi, aj)` pair list in execution order plus the shared inputs,
/// immutable so lanes can share it by `Arc`.
pub struct GridRun {
    /// batch rows
    pub m: usize,
    /// output channels
    pub n: usize,
    /// inner (dot) dimension
    pub k: usize,
    w_planes: Vec<Arc<PackedPlane>>,
    w_scales: Vec<Arc<Vec<f32>>>,
    a_planes: Vec<Arc<PackedPlane>>,
    a_scales: Vec<f32>,
    pairs: Vec<(usize, usize)>,
}

impl GridRun {
    /// Assemble a run. `pairs` index `(w_planes, a_planes)`; weight
    /// planes are `(n, k)`, activation planes `(m, k)`; `w_scales[i]`
    /// is per-channel (len `n`) or a single broadcast scale.
    pub fn new(
        w_planes: Vec<Arc<PackedPlane>>,
        w_scales: Vec<Arc<Vec<f32>>>,
        a_planes: Vec<Arc<PackedPlane>>,
        a_scales: Vec<f32>,
        pairs: Vec<(usize, usize)>,
    ) -> GridRun {
        assert!(!w_planes.is_empty() && !a_planes.is_empty(), "empty grid");
        let (n, k) = (w_planes[0].rows(), w_planes[0].k());
        let m = a_planes[0].rows();
        for p in &w_planes {
            assert_eq!((p.rows(), p.k()), (n, k), "weight plane shape mismatch");
        }
        for p in &a_planes {
            assert_eq!((p.rows(), p.k()), (m, k), "activation plane shape mismatch");
        }
        assert_eq!(w_scales.len(), w_planes.len());
        assert_eq!(a_scales.len(), a_planes.len());
        for &(wi, aj) in &pairs {
            assert!(wi < w_planes.len() && aj < a_planes.len(), "pair out of range");
        }
        GridRun { m, n, k, w_planes, w_scales, a_planes, a_scales, pairs }
    }

    /// Grid cells this run executes.
    pub fn pairs_len(&self) -> usize {
        self.pairs.len()
    }

    /// Total MACs across the pair list.
    pub fn macs(&self) -> usize {
        self.pairs.len() * self.m * self.n * self.k
    }
}

/// Accumulate rows `[r0, r1)` of the grid into `y` (length
/// `(r1-r0)·n`, rows re-based to `r0`). The pair loop is outermost, so
/// each output element receives its `(wi, aj)` contributions in pair
/// order — the bit-identity anchor shared by the sequential and
/// row-parallel drivers and by the scalar reference.
fn execute_rows(run: &GridRun, kernel: Kernel, r0: usize, r1: usize, y: &mut [f32]) {
    let n = run.n;
    debug_assert_eq!(y.len(), (r1 - r0) * n);
    for &(wi, aj) in &run.pairs {
        let s_a = run.a_scales[aj];
        let ws: &[f32] = &run.w_scales[wi];
        let per_ch = ws.len() > 1;
        let wp = &run.w_planes[wi];
        let ap = &run.a_planes[aj];
        let mut jb = 0usize;
        while jb < n {
            let jend = (jb + NC).min(n);
            for i in r0..r1 {
                let arow = ap.row(i);
                let yrow = &mut y[(i - r0) * n..(i - r0 + 1) * n];
                let mut j = jb;
                while j + 4 <= jend {
                    let d = dot4_i8(
                        kernel,
                        arow,
                        [wp.row(j), wp.row(j + 1), wp.row(j + 2), wp.row(j + 3)],
                    );
                    for (u, &dv) in d.iter().enumerate() {
                        let s_w = if per_ch { ws[j + u] } else { ws[0] };
                        yrow[j + u] += s_a * s_w * dv as f32;
                    }
                    j += 4;
                }
                while j < jend {
                    let s_w = if per_ch { ws[j] } else { ws[0] };
                    yrow[j] += s_a * s_w * dot_i8(kernel, arow, wp.row(j)) as f32;
                    j += 1;
                }
            }
            jb = jend;
        }
    }
}

/// Sequentially accumulate the whole grid into `y` (length `m·n`).
pub fn execute(run: &GridRun, kernel: Kernel, y: &mut [f32]) {
    assert_eq!(y.len(), run.m * run.n);
    execute_rows(run, kernel, 0, run.m, y);
}

/// The production entry point: dispatch the active kernel and go
/// row-parallel through the shared pool when the grid is deep enough
/// to amortize it; small grids run inline.
pub fn execute_grid(run: &Arc<GridRun>, y: &mut [f32]) {
    let kernel = active_kernel();
    if run.m >= 2 * parallel::MIN_BLOCK_ROWS && run.macs() >= PAR_MIN_MACS {
        execute_parallel_with(shared(), run, kernel, y);
    } else {
        execute(run, kernel, y);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::{IntTensor, Rng};
    use crate::util::prop::{forall, no_shrink, PropConfig};
    use crate::xint::gemm::int_gemm_scaled_into;

    /// Random plane with values in `[-max_abs, max_abs]`.
    fn rand_plane(rng: &mut Rng, rows: usize, k: usize, max_abs: i32) -> IntTensor {
        let span = (2 * max_abs + 1) as usize;
        let vals = (0..rows * k).map(|_| rng.below(span) as i32 - max_abs).collect();
        IntTensor::from_vec(&[rows, k], vals)
    }

    /// The satellite property: packed grids — portable and active
    /// kernel, m=1 / n=1 / k off the 32-lane width, bits 3/4/8 — are
    /// bit-identical to the scalar `int_gemm_scaled_into` loop over
    /// the same pair order.
    #[test]
    fn property_packed_grid_bit_identical_to_scalar() {
        forall(
            PropConfig { cases: 40, seed: 0x9E11E7, max_shrink: 0 },
            |r| {
                let m = 1 + r.below(5);
                let n = 1 + r.below(9);
                let k = 1 + r.below(70);
                let bits = [3u32, 4, 8][r.below(3)];
                let per_ch = r.below(2) == 1;
                let mut rng = r.fork(9);
                // 8-bit planes cap at 127 here (±128 refuses to pack —
                // covered by the envelope regression tests)
                let max_abs = (1i32 << (bits - 1)).min(127);
                let w_int: Vec<IntTensor> =
                    (0..2).map(|_| rand_plane(&mut rng, n, k, max_abs)).collect();
                let a_int: Vec<IntTensor> =
                    (0..2).map(|_| rand_plane(&mut rng, m, k, max_abs)).collect();
                let w_scales: Vec<Vec<f32>> = (0..2)
                    .map(|_| {
                        let len = if per_ch { n } else { 1 };
                        (0..len).map(|_| rng.uniform(0.001, 2.0)).collect()
                    })
                    .collect();
                let a_scales: Vec<f32> = (0..2).map(|_| rng.uniform(0.001, 2.0)).collect();
                (w_int, a_int, w_scales, a_scales, (m, n))
            },
            no_shrink,
            |(w_int, a_int, w_scales, a_scales, (m, n))| {
                let pairs = vec![(0usize, 0usize), (0, 1), (1, 0), (1, 1)];
                let mut y_ref = vec![0.0f32; m * n];
                for &(wi, aj) in &pairs {
                    int_gemm_scaled_into(
                        &a_int[aj],
                        &w_int[wi],
                        &w_scales[wi],
                        a_scales[aj],
                        &mut y_ref,
                    );
                }
                let run = GridRun::new(
                    w_int.iter().map(|p| Arc::new(PackedPlane::pack(p).unwrap())).collect(),
                    w_scales.iter().map(|s| Arc::new(s.clone())).collect(),
                    a_int.iter().map(|p| Arc::new(PackedPlane::pack(p).unwrap())).collect(),
                    a_scales.clone(),
                    pairs,
                );
                for kernel in [Kernel::Portable, active_kernel()] {
                    let mut y = vec![0.0f32; m * n];
                    execute(&run, kernel, &mut y);
                    if y != y_ref {
                        return Err(format!("{kernel:?} diverged from scalar"));
                    }
                }
                Ok(())
            },
        );
    }

    /// `execute_grid` (auto dispatch, shared pool) stays bit-identical
    /// on a grid deep enough to cross the parallel threshold.
    #[test]
    fn execute_grid_parallel_threshold_bit_identical() {
        let mut rng = Rng::seed(76);
        let (m, n, k) = (64usize, 64usize, 256usize);
        let w_int: Vec<IntTensor> = (0..2).map(|_| rand_plane(&mut rng, n, k, 7)).collect();
        let a_int: Vec<IntTensor> = (0..3).map(|_| rand_plane(&mut rng, m, k, 7)).collect();
        let w_scales: Vec<Vec<f32>> =
            (0..2).map(|_| (0..n).map(|_| rng.uniform(0.01, 1.0)).collect()).collect();
        let a_scales: Vec<f32> = (0..3).map(|_| rng.uniform(0.01, 1.0)).collect();
        let mut pairs = Vec::new();
        for i in 0..2 {
            for j in 0..3 {
                pairs.push((i, j));
            }
        }
        let mut y_ref = vec![0.0f32; m * n];
        for &(wi, aj) in &pairs {
            int_gemm_scaled_into(&a_int[aj], &w_int[wi], &w_scales[wi], a_scales[aj], &mut y_ref);
        }
        let run = Arc::new(GridRun::new(
            w_int.iter().map(|p| Arc::new(PackedPlane::pack(p).unwrap())).collect(),
            w_scales.iter().map(|s| Arc::new(s.clone())).collect(),
            a_int.iter().map(|p| Arc::new(PackedPlane::pack(p).unwrap())).collect(),
            a_scales,
            pairs,
        ));
        assert!(run.macs() >= PAR_MIN_MACS, "test must cross the parallel threshold");
        let mut y = vec![0.0f32; m * n];
        execute_grid(&run, &mut y);
        assert_eq!(y, y_ref);
    }
}
