//! Row-parallel grid driver (tentpole step 3): a small persistent
//! worker set splits one grid run over disjoint output-row blocks.
//!
//! ## Block-handoff protocol (see CONCURRENCY.md)
//!
//! One [`GridJob`] per layer call carries a single `AtomicUsize` claim
//! cursor. Every lane — the pool workers *and* the requesting thread —
//! loops `fetch_add(1)` on the cursor and executes the row block it was
//! handed until the cursor passes `nblocks`. Blocks are disjoint row
//! ranges, each accumulated from zero in lane-local storage (workers)
//! or straight into `y` (the requester), so no two lanes ever write the
//! same output element; worker results travel back over an `mpsc`
//! channel whose send/recv pair carries the happens-before edge for the
//! block payload. The cursor itself therefore needs only RMW atomicity
//! (`Relaxed`), and `loom_model_kernel_block_claim_exactly_once` pins
//! that every block is claimed exactly once with none skipped.
//!
//! The requesting thread claiming alongside the pool is the progress
//! guarantee: if every pool worker is busy with other requests' jobs,
//! the requester simply computes all blocks itself — the pool can slow
//! a call down to sequential speed, never wedge it. A vanished worker
//! degrades the same way: unreceived blocks are recomputed inline.
//!
//! ## Sizing (composes with the per-request pool)
//!
//! Per-call lane count = `min(pool lanes, width cap, m / MIN_BLOCK_ROWS)`.
//! The width cap defaults to unlimited and is lowered by
//! [`set_interop_workers`] when a `coordinator::WorkerPool` spawns:
//! `available_parallelism / request_workers`, so request-level and
//! row-level parallelism multiply out to the machine's core count
//! instead of oversubscribing it. `FP_XINT_KERNEL_THREADS` overrides
//! the shared pool's lane target (the requester counts as one lane).

use crate::util::sync::atomic::{AtomicUsize, Ordering};
use crate::util::sync::{mpsc, thread, Arc, OnceLock};

use super::micro::Kernel;
use super::GridRun;

/// Smallest row block worth handing to a lane; below `2 ×` this the
/// executor stays sequential.
pub const MIN_BLOCK_ROWS: usize = 4;

/// Target claim granularity: enough blocks per lane that an uneven
/// finish rebalances, few enough that claim/send overhead stays noise.
const BLOCKS_PER_LANE: usize = 2;

/// One dispatched grid run: shared immutable inputs plus the claim
/// cursor the lanes race on.
struct GridJob {
    run: Arc<GridRun>,
    kernel: Kernel,
    next: AtomicUsize,
    nblocks: usize,
    block_rows: usize,
}

impl GridJob {
    fn rows(&self, b: usize) -> (usize, usize) {
        let r0 = b * self.block_rows;
        (r0, (r0 + self.block_rows).min(self.run.m))
    }
}

struct RunTask {
    job: Arc<GridJob>,
    out: mpsc::Sender<(usize, Vec<f32>)>,
}

enum Task {
    Run(RunTask),
    Stop,
}

/// Persistent row-block workers (`xint-kernel-{i}` threads). One shared
/// process-wide instance serves every layer call (see [`shared`]);
/// tests and benches build private pools.
pub struct KernelPool {
    senders: Vec<mpsc::Sender<Task>>,
    handles: Vec<thread::JoinHandle<()>>,
}

impl KernelPool {
    /// Spawn `workers` pool threads (the requesting thread is always an
    /// additional lane, so `workers = lanes - 1`).
    pub fn new(workers: usize) -> KernelPool {
        let mut senders = Vec::with_capacity(workers);
        let mut handles = Vec::with_capacity(workers);
        for i in 0..workers {
            let (tx, rx) = mpsc::channel::<Task>();
            handles.push(
                thread::Builder::new()
                    .name(format!("xint-kernel-{i}"))
                    .spawn(move || worker_loop(&rx))
                    .expect("spawn kernel worker"),
            );
            senders.push(tx);
        }
        KernelPool { senders, handles }
    }

    /// Pool worker count (lanes are this + 1).
    pub fn workers(&self) -> usize {
        self.senders.len()
    }

    /// Stop and join the workers — for tests and benches; the shared
    /// pool lives for the process.
    pub fn shutdown(self) {
        for tx in &self.senders {
            let _ = tx.send(Task::Stop);
        }
        for h in self.handles {
            let _ = h.join();
        }
    }
}

fn worker_loop(rx: &mpsc::Receiver<Task>) {
    while let Ok(task) = rx.recv() {
        match task {
            Task::Run(t) => {
                let n = t.job.run.n;
                claim_blocks(&t.job.next, t.job.nblocks, |b| {
                    let (r0, r1) = t.job.rows(b);
                    let mut block = vec![0.0f32; (r1 - r0) * n];
                    super::execute_rows(&t.job.run, t.job.kernel, r0, r1, &mut block);
                    // the requester may have recomputed and left already
                    let _ = t.out.send((b, block));
                });
            }
            Task::Stop => break,
        }
    }
}

/// Race claims off the cursor, running `f(block)` for each claim; stops
/// once the cursor passes `nblocks`. Returns how many blocks this lane
/// executed. Shared verbatim by the pool workers, the requesting
/// thread, and the loom model.
fn claim_blocks(next: &AtomicUsize, nblocks: usize, mut f: impl FnMut(usize)) -> usize {
    let mut claimed = 0usize;
    loop {
        // ordering: Relaxed — the claim cursor only needs RMW atomicity
        // (fetch_add hands out each block index exactly once); block
        // payloads are published through the result channel, whose
        // send/recv pair provides the happens-before edge.
        let b = next.fetch_add(1, Ordering::Relaxed);
        if b >= nblocks {
            return claimed;
        }
        f(b);
        claimed += 1;
    }
}

fn width_cap() -> &'static AtomicUsize {
    static CAP: OnceLock<AtomicUsize> = OnceLock::new();
    CAP.get_or_init(|| AtomicUsize::new(usize::MAX))
}

/// Lower the kernel's per-call lane cap so `request_workers` concurrent
/// layer calls times `cap` row lanes fills — and does not oversubscribe
/// — the machine. Called by `coordinator::WorkerPool::new`; the latest
/// pool's geometry wins.
pub fn set_interop_workers(request_workers: usize) {
    let avail = thread::available_parallelism().map(|v| v.get()).unwrap_or(1);
    let cap = (avail / request_workers.max(1)).max(1);
    // ordering: Relaxed — a sizing hint read at dispatch time; no data
    // is published through this value.
    width_cap().store(cap, Ordering::Relaxed);
}

fn lanes_for(pool: &KernelPool, m: usize) -> usize {
    // ordering: Relaxed — sizing hint only (see set_interop_workers).
    let cap = width_cap().load(Ordering::Relaxed);
    (pool.workers() + 1).min(cap).min(m / MIN_BLOCK_ROWS).max(1)
}

/// The process-wide pool, spawned on first use: lane target from
/// `FP_XINT_KERNEL_THREADS`, else `available_parallelism`.
pub fn shared() -> &'static KernelPool {
    static SHARED: OnceLock<KernelPool> = OnceLock::new();
    SHARED.get_or_init(|| KernelPool::new(default_lanes().saturating_sub(1)))
}

fn default_lanes() -> usize {
    if let Ok(v) = std::env::var("FP_XINT_KERNEL_THREADS") {
        if let Ok(n) = v.parse::<usize>() {
            return n.max(1);
        }
    }
    thread::available_parallelism().map(|v| v.get()).unwrap_or(1)
}

/// Execute `run` into `y` with row blocks split across `pool` plus the
/// calling thread. Bit-identical to [`super::execute`]: blocks are
/// row-disjoint and each accumulates its pairs in the same sequential
/// order, and a worker block starting from zeros then copied equals the
/// in-place accumulation onto the zeroed `y`.
pub fn execute_parallel_with(pool: &KernelPool, run: &Arc<GridRun>, kernel: Kernel, y: &mut [f32]) {
    let (m, n) = (run.m, run.n);
    assert_eq!(y.len(), m * n);
    let lanes = lanes_for(pool, m);
    if lanes <= 1 {
        super::execute(run, kernel, y);
        return;
    }
    let block_rows = m.div_ceil(lanes * BLOCKS_PER_LANE).max(MIN_BLOCK_ROWS);
    let nblocks = m.div_ceil(block_rows);
    if nblocks <= 1 {
        super::execute(run, kernel, y);
        return;
    }
    let job = Arc::new(GridJob {
        run: Arc::clone(run),
        kernel,
        next: AtomicUsize::new(0),
        nblocks,
        block_rows,
    });
    let (tx, rx) = mpsc::channel();
    let mut dispatched = 0usize;
    for s in pool.senders.iter().take(lanes - 1) {
        if s.send(Task::Run(RunTask { job: Arc::clone(&job), out: tx.clone() })).is_ok() {
            dispatched += 1;
        }
    }
    drop(tx);
    let mut done = vec![false; nblocks];
    let mut remaining = nblocks;
    // the requesting thread is a full lane: it claims off the same
    // cursor and writes its blocks straight into `y` (no copy), which
    // also guarantees progress when every pool worker is busy elsewhere
    claim_blocks(&job.next, nblocks, |b| {
        let (r0, r1) = job.rows(b);
        super::execute_rows(run, kernel, r0, r1, &mut y[r0 * n..r1 * n]);
        done[b] = true;
        remaining -= 1;
    });
    if dispatched > 0 {
        while remaining > 0 {
            match rx.recv() {
                Ok((b, block)) => {
                    if !done[b] {
                        let (r0, r1) = job.rows(b);
                        y[r0 * n..r1 * n].copy_from_slice(&block);
                        done[b] = true;
                        remaining -= 1;
                    }
                }
                // every dispatched worker finished or died; fall through
                Err(_) => break,
            }
        }
    }
    // a block claimed by a worker that died before sending is
    // recomputed inline — correctness never depends on the pool
    for b in 0..nblocks {
        if !done[b] {
            let (r0, r1) = job.rows(b);
            super::execute_rows(run, kernel, r0, r1, &mut y[r0 * n..r1 * n]);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::{IntTensor, Rng};
    use crate::xint::kernel::{execute, PackedPlane};

    fn rand_packed(rng: &mut Rng, rows: usize, k: usize) -> Arc<PackedPlane> {
        let vals: Vec<i32> = (0..rows * k).map(|_| rng.below(255) as i32 - 127).collect();
        Arc::new(PackedPlane::pack(&IntTensor::from_vec(&[rows, k], vals)).unwrap())
    }

    fn rand_run(rng: &mut Rng, m: usize, n: usize, k: usize) -> Arc<GridRun> {
        let w_planes: Vec<_> = (0..2).map(|_| rand_packed(rng, n, k)).collect();
        let a_planes: Vec<_> = (0..2).map(|_| rand_packed(rng, m, k)).collect();
        let w_scales: Vec<Arc<Vec<f32>>> = (0..2)
            .map(|_| Arc::new((0..n).map(|_| rng.uniform(0.01, 1.0)).collect()))
            .collect();
        let a_scales: Vec<f32> = (0..2).map(|_| rng.uniform(0.01, 1.0)).collect();
        let pairs = vec![(0, 0), (0, 1), (1, 0), (1, 1)];
        Arc::new(GridRun::new(w_planes, w_scales, a_planes, a_scales, pairs))
    }

    #[test]
    fn parallel_blocks_bit_identical_to_sequential() {
        let mut rng = Rng::seed(74);
        let pool = KernelPool::new(3);
        for &(m, n, k) in &[(64usize, 16usize, 50usize), (33, 7, 100), (9, 3, 20)] {
            let run = rand_run(&mut rng, m, n, k);
            for kernel in [Kernel::Portable, super::super::active_kernel()] {
                let mut y_seq = vec![0.0f32; m * n];
                execute(&run, kernel, &mut y_seq);
                let mut y_par = vec![0.0f32; m * n];
                execute_parallel_with(&pool, &run, kernel, &mut y_par);
                assert_eq!(y_seq, y_par, "m={m} n={n} k={k} {kernel:?}");
            }
        }
        pool.shutdown();
    }

    #[test]
    fn zero_worker_pool_degrades_to_sequential() {
        let mut rng = Rng::seed(75);
        let run = rand_run(&mut rng, 32, 8, 40);
        let pool = KernelPool::new(0);
        let mut y_seq = vec![0.0f32; 32 * 8];
        execute(&run, Kernel::Portable, &mut y_seq);
        let mut y_par = vec![0.0f32; 32 * 8];
        execute_parallel_with(&pool, &run, Kernel::Portable, &mut y_par);
        assert_eq!(y_seq, y_par);
        pool.shutdown();
    }
}

#[cfg(all(test, loom))]
mod loom_models {
    use super::claim_blocks;
    use crate::util::sync::atomic::{AtomicUsize, Ordering};
    use crate::util::sync::{thread, Arc};

    /// The row-block handoff protocol: lanes race `fetch_add` claims
    /// off one cursor. Across all interleavings every block must be
    /// executed exactly once (no double execution — blocks write
    /// disjoint but *owned* output rows) and none skipped (a missed
    /// block would silently zero its output rows).
    #[test]
    fn loom_model_kernel_block_claim_exactly_once() {
        loom::model(|| {
            const BLOCKS: usize = 3;
            let next = Arc::new(AtomicUsize::new(0));
            let hits: Arc<Vec<AtomicUsize>> =
                Arc::new((0..BLOCKS).map(|_| AtomicUsize::new(0)).collect());
            let worker = {
                let next = Arc::clone(&next);
                let hits = Arc::clone(&hits);
                thread::spawn(move || {
                    claim_blocks(&next, BLOCKS, |b| {
                        // ordering: Relaxed — counts are read after join
                        hits[b].fetch_add(1, Ordering::Relaxed);
                    })
                })
            };
            // the requesting thread is itself a lane, exactly as in
            // execute_parallel_with
            let main_claimed = claim_blocks(&next, BLOCKS, |b| {
                // ordering: Relaxed — counts are read after join
                hits[b].fetch_add(1, Ordering::Relaxed);
            });
            let worker_claimed = worker.join().unwrap();
            assert_eq!(main_claimed + worker_claimed, BLOCKS, "blocks lost or duplicated");
            for (b, h) in hits.iter().enumerate() {
                // ordering: Relaxed — join ordered every writer before us
                assert_eq!(h.load(Ordering::Relaxed), 1, "block {b} not executed exactly once");
            }
        });
    }
}
