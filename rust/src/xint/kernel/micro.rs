//! i8 dot micro-kernels and runtime dispatch (tentpole step 2).
//!
//! Two implementations of the same exact-integer dot, selected once per
//! process by feature detection:
//!
//! * **avx2** — `_mm256_maddubs_epi16` widening (i8×i8 → i16 pairs →
//!   i32 lanes → i64), 32 MACs per instruction. `maddubs` wants an
//!   unsigned left operand, so the kernel uses the standard identity
//!   `a·b = |a| · sign_a(b)`; the [`super::pack::PACK_MAX_ABS`] = 127
//!   envelope guarantees the i16 pair sums stay below `2^15` (no
//!   saturation) and that `sign` never wraps, so the result is the
//!   exact integer dot — bit-identical to the scalar path.
//! * **portable** — chunked i32 accumulation with i64 folding, the
//!   same shape as `xint::gemm::int_dot` but over i8 operands; LLVM
//!   autovectorizes it on any target. This is the only path on
//!   non-x86_64 builds and under `FP_XINT_FORCE_PORTABLE`.
//!
//! Both paths fold partial sums into i64 often enough that no i32 lane
//! can overflow (bound stated at [`FOLD_CHUNKS`]), so every kernel
//! returns the mathematically exact dot and the grid output is pinned
//! bit-identical across scalar / portable / AVX2 (tested by
//! `property_packed_grid_bit_identical_to_scalar`).

use crate::util::sync::OnceLock;

/// Which micro-kernel executes the inner dot.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Kernel {
    /// AVX2 `maddubs` widening path (x86_64 with runtime-detected AVX2).
    Avx2,
    /// Scalar-unrolled i8 path (any target; forced by
    /// `FP_XINT_FORCE_PORTABLE=1`).
    Portable,
}

impl Kernel {
    pub fn name(self) -> &'static str {
        match self {
            Kernel::Avx2 => "avx2",
            Kernel::Portable => "portable",
        }
    }
}

/// The kernel the dispatcher selected for this process: AVX2 when the
/// CPU reports it, unless `FP_XINT_FORCE_PORTABLE` is set to anything
/// but `0`/empty (the CI fallback leg runs the whole tier-1 suite this
/// way). Detected once, cached for the process lifetime.
pub fn active_kernel() -> Kernel {
    static ACTIVE: OnceLock<Kernel> = OnceLock::new();
    *ACTIVE.get_or_init(detect)
}

fn detect() -> Kernel {
    if let Ok(v) = std::env::var("FP_XINT_FORCE_PORTABLE") {
        if !v.is_empty() && v != "0" {
            return Kernel::Portable;
        }
    }
    #[cfg(target_arch = "x86_64")]
    {
        if std::arch::is_x86_feature_detected!("avx2") {
            return Kernel::Avx2;
        }
    }
    Kernel::Portable
}

/// Exact i8 dot through the selected kernel.
#[inline]
pub fn dot_i8(kernel: Kernel, a: &[i8], b: &[i8]) -> i64 {
    match kernel {
        #[cfg(target_arch = "x86_64")]
        Kernel::Avx2 => avx2::dot(a, b),
        #[cfg(not(target_arch = "x86_64"))]
        Kernel::Avx2 => dot_i8_portable(a, b),
        Kernel::Portable => dot_i8_portable(a, b),
    }
}

/// Four exact i8 dots sharing the `a` operand (register blocking: the
/// AVX2 path loads and `abs`es each 32-byte `a` chunk once for all four
/// `b` rows — the grid executor walks output columns in strides of 4).
#[inline]
pub fn dot4_i8(kernel: Kernel, a: &[i8], b: [&[i8]; 4]) -> [i64; 4] {
    match kernel {
        #[cfg(target_arch = "x86_64")]
        Kernel::Avx2 => avx2::dot4(a, b),
        #[cfg(not(target_arch = "x86_64"))]
        Kernel::Avx2 => dot4_portable(a, b),
        Kernel::Portable => dot4_portable(a, b),
    }
}

/// How many 32-element chunks accumulate into i32 lanes before folding
/// to i64. Each chunk adds at most `2 · 127² < 2^15` per lane, so 4096
/// chunks stay below `2^27` — far from i32 overflow. (The portable
/// path folds every 256 elements, mirroring `int_dot`.)
const FOLD_CHUNKS: usize = 4096;

/// Scalar-unrolled fallback: chunked i32 partials folded into i64,
/// exactly the `int_dot` recipe narrowed to i8 operands. `|v| ≤ 127`
/// bounds a 256-element partial to `256 · 127² < 2^23 < i32::MAX`.
pub fn dot_i8_portable(a: &[i8], b: &[i8]) -> i64 {
    debug_assert_eq!(a.len(), b.len());
    const CHUNK: usize = 256;
    let mut acc: i64 = 0;
    let mut ai = a.chunks_exact(CHUNK);
    let mut bi = b.chunks_exact(CHUNK);
    for (ca, cb) in (&mut ai).zip(&mut bi) {
        let mut partial: i32 = 0;
        for (&x, &y) in ca.iter().zip(cb) {
            partial += x as i32 * y as i32;
        }
        acc += partial as i64;
    }
    let mut partial: i32 = 0;
    for (&x, &y) in ai.remainder().iter().zip(bi.remainder()) {
        partial += x as i32 * y as i32;
    }
    acc + partial as i64
}

fn dot4_portable(a: &[i8], b: [&[i8]; 4]) -> [i64; 4] {
    [
        dot_i8_portable(a, b[0]),
        dot_i8_portable(a, b[1]),
        dot_i8_portable(a, b[2]),
        dot_i8_portable(a, b[3]),
    ]
}

/// The one sanctioned `unsafe` island in the crate (see the lib-level
/// `deny(unsafe_code)` note): raw AVX2 intrinsics behind runtime
/// feature detection. The public functions here are *safe*: they
/// re-check `is_x86_feature_detected!` (a cached atomic load) before
/// entering the `target_feature` functions, so even a hand-constructed
/// [`Kernel::Avx2`] on a non-AVX2 host degrades to the portable path
/// instead of hitting an illegal instruction.
#[cfg(target_arch = "x86_64")]
#[allow(unsafe_code)]
mod avx2 {
    use core::arch::x86_64::{
        __m256i, _mm256_abs_epi8, _mm256_add_epi32, _mm256_loadu_si256, _mm256_madd_epi16,
        _mm256_maddubs_epi16, _mm256_set1_epi16, _mm256_setzero_si256, _mm256_sign_epi8,
        _mm256_storeu_si256,
    };

    use super::FOLD_CHUNKS;

    pub fn dot(a: &[i8], b: &[i8]) -> i64 {
        assert_eq!(a.len(), b.len());
        if std::arch::is_x86_feature_detected!("avx2") {
            // SAFETY: AVX2 presence just verified; slices are equal
            // length and loadu/storeu tolerate any alignment.
            unsafe { dot_avx2(a, b) }
        } else {
            super::dot_i8_portable(a, b)
        }
    }

    pub fn dot4(a: &[i8], b: [&[i8]; 4]) -> [i64; 4] {
        for r in &b {
            assert_eq!(a.len(), r.len());
        }
        if std::arch::is_x86_feature_detected!("avx2") {
            // SAFETY: AVX2 presence just verified; slices are equal
            // length and loadu/storeu tolerate any alignment.
            unsafe { dot4_avx2(a, b) }
        } else {
            super::dot4_portable(a, b)
        }
    }

    /// Sum the eight i32 lanes into i64.
    ///
    /// # Safety
    /// Caller must have verified AVX2 support.
    #[target_feature(enable = "avx2")]
    unsafe fn hsum_i32x8(v: __m256i) -> i64 {
        let mut lanes = [0i32; 8];
        _mm256_storeu_si256(lanes.as_mut_ptr() as *mut __m256i, v);
        lanes.iter().map(|&x| x as i64).sum()
    }

    /// # Safety
    /// Caller must have verified AVX2 support and `a.len() == b.len()`.
    #[target_feature(enable = "avx2")]
    unsafe fn dot_avx2(a: &[i8], b: &[i8]) -> i64 {
        let n = a.len();
        let ones = _mm256_set1_epi16(1);
        let mut acc = _mm256_setzero_si256();
        let mut total: i64 = 0;
        let mut folds = 0usize;
        let mut i = 0usize;
        while i + 32 <= n {
            let va = _mm256_loadu_si256(a.as_ptr().add(i) as *const __m256i);
            let vb = _mm256_loadu_si256(b.as_ptr().add(i) as *const __m256i);
            // a·b = |a| · sign_a(b); |v| ≤ 127 ⇒ pair sums < 2^15, so
            // maddubs cannot saturate and sign cannot wrap — exact.
            let pairs = _mm256_maddubs_epi16(_mm256_abs_epi8(va), _mm256_sign_epi8(vb, va));
            acc = _mm256_add_epi32(acc, _mm256_madd_epi16(pairs, ones));
            i += 32;
            folds += 1;
            if folds == FOLD_CHUNKS {
                total += hsum_i32x8(acc);
                acc = _mm256_setzero_si256();
                folds = 0;
            }
        }
        total += hsum_i32x8(acc);
        for (&x, &y) in a[i..].iter().zip(&b[i..]) {
            total += x as i64 * y as i64;
        }
        total
    }

    /// # Safety
    /// Caller must have verified AVX2 support and that all five slices
    /// have equal length.
    #[target_feature(enable = "avx2")]
    unsafe fn dot4_avx2(a: &[i8], b: [&[i8]; 4]) -> [i64; 4] {
        let n = a.len();
        let ones = _mm256_set1_epi16(1);
        let mut acc = [_mm256_setzero_si256(); 4];
        let mut total = [0i64; 4];
        let mut folds = 0usize;
        let mut i = 0usize;
        while i + 32 <= n {
            let va = _mm256_loadu_si256(a.as_ptr().add(i) as *const __m256i);
            let abs_a = _mm256_abs_epi8(va);
            for (r, acc_r) in acc.iter_mut().enumerate() {
                let vb = _mm256_loadu_si256(b[r].as_ptr().add(i) as *const __m256i);
                let pairs = _mm256_maddubs_epi16(abs_a, _mm256_sign_epi8(vb, va));
                *acc_r = _mm256_add_epi32(*acc_r, _mm256_madd_epi16(pairs, ones));
            }
            i += 32;
            folds += 1;
            if folds == FOLD_CHUNKS {
                for (t, acc_r) in total.iter_mut().zip(&mut acc) {
                    *t += hsum_i32x8(*acc_r);
                    *acc_r = _mm256_setzero_si256();
                }
                folds = 0;
            }
        }
        for r in 0..4 {
            total[r] += hsum_i32x8(acc[r]);
            for (&x, &y) in a[i..].iter().zip(&b[r][i..]) {
                total[r] += x as i64 * y as i64;
            }
        }
        total
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Rng;

    fn reference(a: &[i8], b: &[i8]) -> i64 {
        a.iter().zip(b).map(|(&x, &y)| x as i64 * y as i64).sum()
    }

    fn rand_row(rng: &mut Rng, n: usize) -> Vec<i8> {
        (0..n).map(|_| (rng.below(255) as i32 - 127) as i8).collect()
    }

    #[test]
    fn dots_exact_across_lengths_and_kernels() {
        let mut rng = Rng::seed(72);
        // lengths straddling the 32-lane width, the 256 fold chunk, and
        // the degenerate 0/1 cases
        for n in [0usize, 1, 7, 31, 32, 33, 64, 100, 255, 256, 257, 1000] {
            let a = rand_row(&mut rng, n);
            let b = rand_row(&mut rng, n);
            let want = reference(&a, &b);
            for kernel in [Kernel::Portable, active_kernel()] {
                assert_eq!(dot_i8(kernel, &a, &b), want, "n={n} {kernel:?}");
            }
        }
    }

    #[test]
    fn dot4_matches_four_dots() {
        let mut rng = Rng::seed(73);
        for n in [1usize, 33, 100, 257] {
            let a = rand_row(&mut rng, n);
            let rows: Vec<Vec<i8>> = (0..4).map(|_| rand_row(&mut rng, n)).collect();
            let want: Vec<i64> = rows.iter().map(|r| reference(&a, r)).collect();
            for kernel in [Kernel::Portable, active_kernel()] {
                let got = dot4_i8(kernel, &a, [&rows[0], &rows[1], &rows[2], &rows[3]]);
                assert_eq!(got.to_vec(), want, "n={n} {kernel:?}");
            }
        }
    }

    #[test]
    fn extreme_envelope_values_stay_exact() {
        // ±127 everywhere is the worst case for the maddubs pair sums
        // (2·127² = 32258, just under i16::MAX) and for lane growth
        let n = 8192;
        let a = vec![127i8; n];
        let mut b = vec![-127i8; n];
        // alternate signs so sign_a(b) exercises both directions
        for (i, v) in b.iter_mut().enumerate() {
            if i % 2 == 0 {
                *v = 127;
            }
        }
        let want = reference(&a, &b);
        for kernel in [Kernel::Portable, active_kernel()] {
            assert_eq!(dot_i8(kernel, &a, &b), want, "{kernel:?}");
        }
    }

    #[test]
    fn fold_boundary_crossing_stays_exact() {
        // straddle the i64 fold trigger at maximal magnitude: the first
        // FOLD_CHUNKS 32-lane chunks grow the i32 lanes to the proven
        // bound 4·127²·FOLD_CHUNKS, then 35 extra elements force a
        // partial chunk after the fold (runs in release CI, so the
        // overflow check is the arithmetic itself, not a debug_assert)
        let n = 32 * FOLD_CHUNKS + 35;
        let mut a = vec![127i8; n];
        let mut b = vec![-127i8; n];
        for (i, v) in a.iter_mut().enumerate() {
            if i % 3 == 0 {
                *v = -127;
            }
        }
        for (i, v) in b.iter_mut().enumerate() {
            if i % 5 == 0 {
                *v = 127;
            }
        }
        let want = reference(&a, &b);
        for kernel in [Kernel::Portable, active_kernel()] {
            assert_eq!(dot_i8(kernel, &a, &b), want, "{kernel:?}");
            let got = dot4_i8(kernel, &a, [&b, &b, &b, &b]);
            assert_eq!(got.to_vec(), vec![want; 4], "dot4 {kernel:?}");
        }
    }
}
