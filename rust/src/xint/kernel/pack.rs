//! i8 plane packing (tentpole step 1: pack once, reuse across the grid).
//!
//! A [`PackedPlane`] is a basis plane narrowed from the `IntTensor`'s
//! `i32` storage to row-major `i8`, plus per-row value sums. Weight
//! planes pack once at `ExpandedWeight::new` (load time); activation
//! planes pack once per layer call and are then reused by every weight
//! term of the Eq. 3 grid — the packing cost amortizes over `k` GEMMs,
//! and the i8 rows quarter the memory traffic of the scalar kernel.

use crate::tensor::IntTensor;
use crate::xint::gemm::{debug_assert_envelope, INT_DOT_MAX_ABS};

/// i8-pack eligibility envelope: every plane value must satisfy
/// `|v| ≤ PACK_MAX_ABS` (= 127). This is strictly tighter than the
/// i8 range on purpose: the AVX2 micro-kernel computes `a·b` as
/// `|a| · sign_a(b)` (`maddubs` identity), and `sign_a(-128)` wraps —
/// so magnitude is capped at 127 on both operands, which also bounds the
/// `maddubs` pair sums to `2·127² < 2^15` (no i16 saturation). Planes
/// with X ≤ 7 always fit (`half = 64`); X = 8 planes fit unless a
/// saturating value hits ±128, in which case [`PackedPlane::pack`]
/// returns `None` and the grid runs on the exact scalar kernel
/// instead. Wider planes (up to X = 12) stay inside the shared
/// [`INT_DOT_MAX_ABS`] envelope and always have the scalar path.
pub const PACK_MAX_ABS: i32 = 127;

/// One basis plane packed to row-major `i8` with row-sum metadata.
#[derive(Clone, Debug)]
pub struct PackedPlane {
    rows: usize,
    k: usize,
    data: Vec<i8>,
    /// `Σ_c plane[r, c]` per row — the rank-1 `bias_w` path reads these
    /// instead of recomputing O(rows·k) sums per request.
    row_sums: Vec<i64>,
}

impl PackedPlane {
    /// Pack a rank-2 plane, or `None` if any value falls outside the
    /// [`PACK_MAX_ABS`] envelope (the caller then keeps the scalar
    /// kernel, which is exact up to [`INT_DOT_MAX_ABS`]).
    pub fn pack(plane: &IntTensor) -> Option<PackedPlane> {
        let dims = plane.dims();
        assert_eq!(dims.len(), 2, "PackedPlane wants a rank-2 plane");
        let (rows, k) = (dims[0], dims[1]);
        assert!(k > 0, "PackedPlane wants a nonzero inner dim");
        debug_assert_envelope(plane.data(), INT_DOT_MAX_ABS, "PackedPlane::pack");
        let mut data = Vec::with_capacity(rows * k);
        let mut row_sums = Vec::with_capacity(rows);
        for src in plane.data().chunks_exact(k) {
            let mut sum = 0i64;
            for &v in src {
                if v.abs() > PACK_MAX_ABS {
                    return None;
                }
                data.push(v as i8);
                sum += v as i64;
            }
            row_sums.push(sum);
        }
        Some(PackedPlane { rows, k, data, row_sums })
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    /// Inner (dot) dimension.
    pub fn k(&self) -> usize {
        self.k
    }

    /// Row `r` as a contiguous i8 slice.
    #[inline]
    pub fn row(&self, r: usize) -> &[i8] {
        &self.data[r * self.k..(r + 1) * self.k]
    }

    /// Per-row value sums (exact i64, same values the scalar `bias_w`
    /// path derives per request).
    pub fn row_sums(&self) -> &[i64] {
        &self.row_sums
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Rng;

    #[test]
    fn pack_roundtrips_values_and_row_sums() {
        let mut rng = Rng::seed(71);
        let (rows, k) = (5, 37);
        let vals: Vec<i32> = (0..rows * k).map(|_| rng.below(255) as i32 - 127).collect();
        let plane = IntTensor::from_vec(&[rows, k], vals.clone());
        let p = PackedPlane::pack(&plane).expect("within envelope");
        assert_eq!((p.rows(), p.k()), (rows, k));
        for r in 0..rows {
            for c in 0..k {
                assert_eq!(p.row(r)[c] as i32, vals[r * k + c]);
            }
            let want: i64 = vals[r * k..(r + 1) * k].iter().map(|&v| v as i64).sum();
            assert_eq!(p.row_sums()[r], want, "row {r}");
        }
    }

    #[test]
    fn envelope_overflow_refuses_to_pack() {
        // X = 8 saturating planes contain ±128 — exactly one value out
        // of envelope must already force the scalar fallback (the
        // maddubs sign trick would wrap on ±128)
        for bad in [128, -128, 2047] {
            let mut vals = vec![1i32; 64];
            vals[17] = bad;
            assert!(
                PackedPlane::pack(&IntTensor::from_vec(&[2, 32], vals)).is_none(),
                "value {bad} must not pack"
            );
        }
        // ±127 is the inclusive edge and must pack
        let edge = IntTensor::from_vec(&[2, 32], vec![127i32; 64]);
        assert!(PackedPlane::pack(&edge).is_some());
        let edge_neg = IntTensor::from_vec(&[2, 32], vec![-127i32; 64]);
        assert!(PackedPlane::pack(&edge_neg).is_some());
    }

    #[test]
    fn boundary_plane_packs_exact_at_maximal_k() {
        // |v| == PACK_MAX_ABS across a K far past one AVX2 fold cadence:
        // the envelope's worst case for packing and the i64 row sums
        let k = 200_000;
        let vals: Vec<i32> =
            (0..2 * k).map(|i| if i % 3 == 0 { -PACK_MAX_ABS } else { PACK_MAX_ABS }).collect();
        let plane = IntTensor::from_vec(&[2, k], vals.clone());
        let p = PackedPlane::pack(&plane).expect("edge values are inside the envelope");
        for r in 0..2 {
            let want: i64 = vals[r * k..(r + 1) * k].iter().map(|&v| v as i64).sum();
            assert_eq!(p.row_sums()[r], want, "row {r}");
            assert_eq!(p.row(r)[k - 1] as i32, vals[(r + 1) * k - 1]);
        }
    }
}
