//! END-TO-END driver (the DESIGN.md deliverable (b)/EXPERIMENTS.md run):
//! proves all three layers compose on a real small workload.
//!
//! 1. TRAIN a residual CNN on the synthetic image dataset in Rust,
//!    logging the loss curve (the "pretrained FP model" PTQ assumes).
//! 2. QUANTIZE it with the paper's series expansion at W4A4 / W2A4 /
//!    W2A2 and with the RTN baseline; report accuracy for each.
//! 3. SERVE through the full stack: the MLP head case goes through the
//!    AOT-compiled PJRT artifacts (Layer 1 Pallas kernels inside the
//!    Layer 2 HLO, executed by the Layer 3 coordinator with dynamic
//!    batching + AbelianAdd AllReduce over basis workers), driven by a
//!    Poisson request trace; report latency/throughput.
//!
//!     cargo run --release --example e2e_train_quantize_serve

use fp_xint::baselines::{PtqMethod, Rtn};
use fp_xint::coordinator::{BatcherConfig, Coordinator, ExpansionScheduler, WorkerPool};
use fp_xint::datasets::{accuracy, RequestTrace, SynthImg};
use fp_xint::models::{quantized, zoo};
use fp_xint::serve::workers::{mlp_basis_factory, pjrt_mlp_basis_factory, MlpWeights};
use fp_xint::serve::{loadgen, serve_tcp};
use fp_xint::tensor::{Rng, Tensor};
use fp_xint::train::{train_classifier, TrainConfig};
use fp_xint::util::{logger, Table};
use fp_xint::xint::layer::LayerPolicy;
use std::sync::Arc;

fn main() {
    logger::init(false);
    println!("=== Stage 1: train the FP CNN (substrate for PTQ) ===");
    let data = SynthImg::standard(17);
    let mut cnn = zoo::mini_resnet_a(10, 21);
    println!("model {} ({} params)", cnn.name, cnn.params());
    let cfg = TrainConfig { steps: 300, batch: 32, lr: 0.05, log_every: 30 };
    let report = train_classifier(&mut cnn, &data, &cfg);
    println!("loss curve:");
    for (step, loss) in &report.loss_curve {
        let bar = "#".repeat(((loss * 20.0) as usize).min(60));
        println!("  step {step:>4}  loss {loss:.4}  {bar}");
    }
    println!(
        "final: train acc {:.2}%  val acc {:.2}%",
        report.final_train_acc * 100.0,
        report.final_val_acc * 100.0
    );

    println!("\n=== Stage 2: PTQ — series expansion vs RTN ===");
    let val = data.batch(512, 2);
    let calib = data.batch(32, 3).x;
    let mut t = Table::new("CNN accuracy after PTQ", &["setting", "ours (series)", "RTN"]);
    for (wb, ab) in [(4u32, 4u32), (2, 4), (2, 2)] {
        let q = quantized::quantize_model(&cnn, LayerPolicy::new(wb, ab));
        let ours = accuracy(&q.forward(&val.x), &val.y);
        let rtn = Rtn.quantize(&cnn, wb, ab, &calib);
        let base = accuracy(&rtn.forward(&val.x), &val.y);
        t.row_str(&[
            &format!("W{wb}A{ab}"),
            &format!("{:.2}%", ours * 100.0),
            &format!("{:.2}%", base * 100.0),
        ]);
    }
    t.row_str(&["Full Prec.", &format!("{:.2}%", report.final_val_acc * 100.0), "-"]);
    t.print();

    println!("\n=== Stage 3: serve basis models through the coordinator ===");
    // MLP head case uses the AOT artifacts (geometry from the manifest)
    let artifact_dir = fp_xint::runtime::Runtime::default_artifact_dir();
    let have_artifacts = artifact_dir.join("manifest.json").exists();
    let mut mlp = zoo::mlp(256, &[64], 10, 23);
    let mlp_report = train_classifier(&mut mlp, &data, &cfg);
    println!("MLP val acc {:.2}%", mlp_report.final_val_acc * 100.0);
    mlp.fold_bn();
    let weights = extract_mlp(&mlp);
    let terms = 3;
    let factory = if have_artifacts {
        println!("worker backend: PJRT (AOT artifacts from {artifact_dir:?})");
        pjrt_mlp_basis_factory(artifact_dir, &weights, 4, terms)
    } else {
        println!("worker backend: native (run `make artifacts` for the PJRT path)");
        mlp_basis_factory(&weights, 4, terms)
    };
    let pool = WorkerPool::new(terms, factory);
    let coord = Arc::new(Coordinator::new(
        BatcherConfig::uniform(32, 1_000, 256),
        ExpansionScheduler::new(pool),
    ));

    // sanity: served prediction ≈ native quantized prediction
    let mut rng = Rng::seed(3);
    let probe = Tensor::randn(&[4, 256], 1.0, &mut rng);
    let served = coord.infer(probe.clone()).expect("infer");
    println!("served logits shape {:?}", served.logits.dims());

    // serve a TCP endpoint too, proving the wire path
    let handle = serve_tcp("127.0.0.1:0", coord.clone()).expect("bind");
    let via_tcp = fp_xint::serve::server::client_infer(handle.addr, &probe).expect("tcp");
    assert_eq!(via_tcp.dims(), served.logits.dims());
    println!("TCP round-trip OK on {}", handle.addr);

    // trace-driven load
    let trace = RequestTrace::new(150.0, 99);
    let report = loadgen::run_trace(&coord, &trace, 2.0, 256, 0.5);
    println!("load test: {report}");
    let s = coord.metrics.latency_summary();
    let mut t = Table::new("serving metrics", &["metric", "value"]);
    t.row_str(&["completed", &coord.metrics.completed().to_string()]);
    t.row_str(&["mean batch size", &format!("{:.2}", coord.metrics.mean_batch_size())]);
    t.row_str(&["p50 latency", &format!("{:.2} ms", s.p50 * 1e3)]);
    t.row_str(&["p99 latency", &format!("{:.2} ms", s.p99 * 1e3)]);
    t.row_str(&["throughput", &format!("{:.1} req/s", report.throughput_rps)]);
    t.print();
    handle.stop();
    println!("\nE2E OK — all three layers composed.");
}

fn extract_mlp(model: &fp_xint::models::Model) -> MlpWeights {
    use fp_xint::models::Layer;
    let linears: Vec<_> = model
        .layers
        .iter()
        .filter_map(|l| match l {
            Layer::Linear(lin) => Some(lin),
            _ => None,
        })
        .collect();
    MlpWeights {
        w1: linears[0].w.clone(),
        b1: linears[0].b.clone().unwrap(),
        w2: linears[1].w.clone(),
        b2: linears[1].b.clone().unwrap(),
    }
}
