//! QoS serving demo — anytime precision over the TCP wire.
//!
//! Phase 1 drives the server with mixed-tier traffic and prints a
//! per-tier latency/terms/precision table: `throughput`-tier requests
//! reduce only a prefix of the basis pool, so their tail latency sits
//! below `exact`'s.
//!
//! Phase 2 replays the same paced load spike against (a) the seed
//! batcher config (no controller: shed-on-full) and (b) the QoS
//! controller (degrade-precision): the controller lowers term budgets
//! under queue pressure and completes everything, then restores full
//! precision as the queue drains.
//!
//!     cargo run --release --example qos_serving

use fp_xint::coordinator::{BatcherConfig, Coordinator, ExpansionScheduler, WorkerPool};
use fp_xint::qos::{QosConfig, TermController, Tier};
use fp_xint::serve::server::{client_infer_tier, serve_tcp};
use fp_xint::serve::workers::{mlp_basis_factory_with, BiasPlacement, MlpWeights};
use fp_xint::tensor::{Rng, Tensor};
use fp_xint::util::{logger, Summary, Table};
use fp_xint::xint::{BitSpec, ExpandConfig, ExpansionMonitor};
use std::net::SocketAddr;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

const TERMS: usize = 8;
const BITS: u32 = 4;
const DIN: usize = 256;
const HIDDEN: usize = 512;
const REQ_ROWS: usize = 8;

fn weights(seed: u64) -> MlpWeights {
    let mut rng = Rng::seed(seed);
    MlpWeights {
        w1: Tensor::randn(&[HIDDEN, DIN], 0.3, &mut rng),
        b1: Tensor::randn(&[HIDDEN], 0.1, &mut rng),
        w2: Tensor::randn(&[10, HIDDEN], 0.3, &mut rng),
        b2: Tensor::randn(&[10], 0.1, &mut rng),
    }
}

fn calibrated_controller() -> Arc<TermController> {
    let mut mon = ExpansionMonitor::new();
    let cfg = ExpandConfig::symmetric(BitSpec::int(BITS), TERMS);
    let mut rng = Rng::seed(13);
    for _ in 0..4 {
        mon.observe(&Tensor::randn(&[32, DIN], 1.0, &mut rng), &cfg)
            .expect("one config per monitor series");
    }
    let ctl = TermController::new(QosConfig::new(TERMS));
    ctl.calibrate(&mon);
    Arc::new(ctl)
}

fn start_server(
    w: &MlpWeights,
    queue_cap: usize,
    controller: Option<Arc<TermController>>,
) -> (fp_xint::serve::TcpServerHandle, Arc<Coordinator>) {
    let pool =
        WorkerPool::new(TERMS, mlp_basis_factory_with(w, BITS, TERMS, BiasPlacement::FirstTerm));
    let mut sched = ExpansionScheduler::new(pool);
    if let Some(c) = controller {
        sched = sched.with_controller(c);
    }
    let coord = Arc::new(Coordinator::new(
        BatcherConfig::uniform(16, 1_000, queue_cap),
        sched,
    ));
    let handle = serve_tcp("127.0.0.1:0", coord.clone()).expect("bind");
    (handle, coord)
}

/// Single-stream closed-loop seconds per request at `tier`.
fn probe_latency(addr: SocketAddr, tier: Tier, reps: usize) -> f64 {
    let mut rng = Rng::seed(7 + tier.idx() as u64);
    let x = Tensor::randn(&[REQ_ROWS, DIN], 1.0, &mut rng);
    // warm-up
    let _ = client_infer_tier(addr, &x, tier);
    let t0 = Instant::now();
    for _ in 0..reps {
        client_infer_tier(addr, &x, tier).expect("probe");
    }
    t0.elapsed().as_secs_f64() / reps as f64
}

/// Open-loop paced spike: `n` requests at `rate_rps`, tiers cycled over
/// the non-Exact ladder. Returns (completed, shed/errored, p99 seconds).
fn paced_spike(addr: SocketAddr, n: usize, rate_rps: f64) -> (usize, usize, f64) {
    let lat = Arc::new(Mutex::new(Vec::<f64>::new()));
    let errs = Arc::new(Mutex::new(0usize));
    let tiers = [Tier::Balanced, Tier::Throughput, Tier::BestEffort];
    let mut rng = Rng::seed(23);
    let mut handles = Vec::with_capacity(n);
    let gap = Duration::from_secs_f64(1.0 / rate_rps);
    let t0 = Instant::now();
    for i in 0..n {
        let target = gap * i as u32;
        let elapsed = t0.elapsed();
        if target > elapsed {
            std::thread::sleep(target - elapsed);
        }
        let tier = tiers[i % tiers.len()];
        let x = Tensor::randn(&[REQ_ROWS, DIN], 1.0, &mut rng);
        let lat = lat.clone();
        let errs = errs.clone();
        handles.push(std::thread::spawn(move || {
            let sent = Instant::now();
            match client_infer_tier(addr, &x, tier) {
                Ok(_) => lat.lock().unwrap().push(sent.elapsed().as_secs_f64()),
                Err(_) => *errs.lock().unwrap() += 1,
            }
        }));
    }
    for h in handles {
        let _ = h.join();
    }
    let lats = lat.lock().unwrap().clone();
    let p99 = Summary::of(&lats).p99;
    let completed = lats.len();
    let failed = *errs.lock().unwrap();
    (completed, failed, p99)
}

fn main() {
    logger::init(false);
    let w = weights(71);
    let ctl = calibrated_controller();
    let snap = ctl.snapshot();
    println!("calibrated term budgets per tier: {:?}", snap.budgets);
    println!(
        "calibrated layer budgets (replication mode, w×a caps): {:?}",
        snap.layer_budgets.iter().map(|b| b.to_string()).collect::<Vec<_>>()
    );

    // ---------- phase 1: steady mixed-tier traffic ----------
    let (handle, coord) = start_server(&w, 256, Some(ctl.clone()));
    let addr = handle.addr;
    let lat = Arc::new(Mutex::new(Vec::<(Tier, f64)>::new()));
    let clients: Vec<_> = (0..4)
        .map(|c| {
            let lat = lat.clone();
            std::thread::spawn(move || {
                let mut rng = Rng::seed(100 + c);
                for i in 0..40 {
                    let tier = Tier::ALL[(c as usize + i) % Tier::ALL.len()];
                    let x = Tensor::randn(&[REQ_ROWS, DIN], 1.0, &mut rng);
                    let sent = Instant::now();
                    client_infer_tier(addr, &x, tier).expect("steady request");
                    lat.lock().unwrap().push((tier, sent.elapsed().as_secs_f64()));
                    std::thread::sleep(Duration::from_millis(2));
                }
            })
        })
        .collect();
    for c in clients {
        c.join().unwrap();
    }
    let lats = lat.lock().unwrap().clone();
    let mut t1 = Table::new(
        "phase 1 — mixed-tier TCP traffic (4 clients × 40 requests)",
        &["tier", "completed", "p50 (ms)", "p99 (ms)", "mean terms", "est loss"],
    );
    let mut p99 = [0.0f64; 4];
    for tier in Tier::ALL {
        let tl: Vec<f64> =
            lats.iter().filter(|&&(t, _)| t == tier).map(|&(_, l)| l).collect();
        let s = Summary::of(&tl);
        p99[tier.idx()] = s.p99;
        t1.row_str(&[
            tier.name(),
            &tl.len().to_string(),
            &format!("{:.2}", s.p50 * 1e3),
            &format!("{:.2}", s.p99 * 1e3),
            &format!("{:.2}", coord.metrics.tier_mean_terms(tier)),
            &format!("{:.2e}", coord.metrics.tier_est_loss(tier)),
        ]);
    }
    t1.print();
    let sep = p99[Tier::Throughput.idx()] < p99[Tier::Exact.idx()];
    println!(
        "throughput p99 {:.2} ms {} exact p99 {:.2} ms  [{}]",
        p99[Tier::Throughput.idx()] * 1e3,
        if sep { "<" } else { "!<" },
        p99[Tier::Exact.idx()] * 1e3,
        if sep { "OK" } else { "UNEXPECTED" }
    );

    // calibrate the spike rate between the full-precision and degraded
    // service rates (measured, so the demo is host-independent)
    let t_exact = probe_latency(addr, Tier::Exact, 8);
    let t_cheap = probe_latency(addr, Tier::BestEffort, 8);
    handle.stop();
    let r_exact = 1.0 / t_exact;
    let r_cheap = 1.0 / t_cheap;
    // 3× the closed-loop Exact rate: safely above the seed config's
    // open-loop capacity (~2× via batching), safely below the degraded
    // capacity (~2·r_cheap, with r_cheap ≈ 4·r_exact on few cores)
    let spike_rate = r_exact * 3.0;
    println!(
        "\nprobed closed-loop rates: exact {:.0} rps, degraded {:.0} rps → spike at {:.0} rps",
        r_exact, r_cheap, spike_rate
    );

    // ---------- phase 2: load spike, seed config vs controller ----------
    let n_spike = ((spike_rate * 2.0) as usize).clamp(150, 600); // ~2 s of overload
    let queue_cap = 64;

    let (seed_handle, seed_coord) = start_server(&w, queue_cap, None);
    let (s_ok, s_shed, s_p99) = paced_spike(seed_handle.addr, n_spike, spike_rate);
    seed_handle.stop();
    let seed_be_terms = seed_coord.metrics.tier_mean_terms(Tier::BestEffort);

    let ctl2 = calibrated_controller();
    let (qos_handle, qos_coord) = start_server(&w, queue_cap, Some(ctl2.clone()));
    let (q_ok, q_shed, q_p99) = paced_spike(qos_handle.addr, n_spike, spike_rate);
    let peak_pressure = ctl2.snapshot();

    let mut t2 = Table::new(
        &format!("phase 2 — {n_spike} requests at {spike_rate:.0} rps, queue_cap {queue_cap}"),
        &["config", "completed", "shed", "p99 (ms)", "mean terms (BE)"],
    );
    t2.row_str(&[
        "seed (shed-on-full)",
        &s_ok.to_string(),
        &s_shed.to_string(),
        &format!("{:.2}", s_p99 * 1e3),
        &format!("{:.2}", seed_be_terms),
    ]);
    t2.row_str(&[
        "qos (degrade-precision)",
        &q_ok.to_string(),
        &q_shed.to_string(),
        &format!("{:.2}", q_p99 * 1e3),
        &format!("{:.2}", qos_coord.metrics.tier_mean_terms(Tier::BestEffort)),
    ]);
    t2.print();
    for tier in [Tier::Balanced, Tier::Throughput, Tier::BestEffort] {
        println!(
            "  per-tier admission — {tier}: seed shed {}, qos shed {}",
            seed_coord.tier_shed(tier),
            qos_coord.tier_shed(tier)
        );
    }
    println!(
        "controller after spike: per-tier pressure {:?} (degrade events {}, restore events {})",
        peak_pressure.pressures, peak_pressure.degrade_events, peak_pressure.restore_events
    );

    // drain: light traffic restores full precision
    std::thread::sleep(Duration::from_millis(200));
    for _ in 0..30 {
        let mut rng = Rng::seed(31);
        let x = Tensor::randn(&[1, DIN], 1.0, &mut rng);
        let _ = client_infer_tier(qos_handle.addr, &x, Tier::Balanced);
        std::thread::sleep(Duration::from_millis(5));
    }
    let drained = ctl2.snapshot();
    println!(
        "after drain: per-tier pressure {:?} → budgets {:?} (full precision restored: {})",
        drained.pressures,
        drained.budgets,
        drained.pressures.iter().all(|&p| p == 0)
    );
    qos_handle.stop();

    let spike_ok = s_shed > 0 && q_shed == 0;
    println!(
        "\nverdict: seed shed {s_shed}, qos shed {q_shed}  [{}]",
        if spike_ok { "OK — precision degraded, availability held" } else { "UNEXPECTED" }
    );
}
