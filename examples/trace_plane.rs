//! Trace-plane demo — request-scoped spans and the metrics exposition
//! over the TCP wire.
//!
//! Starts the serving coordinator with the flight recorder armed,
//! drives mixed-tier traffic from concurrent clients, then pulls both
//! export surfaces through their control frames: the Prometheus-style
//! text exposition (written to `exposition.txt`) and the Chrome-trace
//! JSON dump of the recorder (written to `trace.json` — open it in
//! Perfetto, ui.perfetto.dev, or chrome://tracing). CI lints the
//! exposition with `scripts/check_exposition.py` and uploads the trace
//! as a sample artifact.
//!
//!     cargo run --release --example trace_plane [-- OUTDIR]

use fp_xint::coordinator::{BatcherConfig, Coordinator, ExpansionScheduler, WorkerPool};
use fp_xint::obs::TraceRecorder;
use fp_xint::qos::{QosConfig, TermController, Tier};
use fp_xint::serve::server::{client_infer_traced, client_metrics, client_trace_json, serve_tcp};
use fp_xint::serve::workers::{mlp_basis_factory_with, BiasPlacement, MlpWeights};
use fp_xint::tensor::{Rng, Tensor};
use fp_xint::util::logger;
use fp_xint::xint::{BitSpec, ExpandConfig, ExpansionMonitor};
use std::sync::Arc;

const TERMS: usize = 8;
const BITS: u32 = 4;
const DIN: usize = 256;

fn main() {
    logger::init(false);
    let outdir = std::env::args().nth(1).unwrap_or_else(|| ".".to_string());
    let mut rng = Rng::seed(77);
    let w = MlpWeights {
        w1: Tensor::randn(&[128, DIN], 0.3, &mut rng),
        b1: Tensor::randn(&[128], 0.1, &mut rng),
        w2: Tensor::randn(&[10, 128], 0.3, &mut rng),
        b2: Tensor::randn(&[10], 0.1, &mut rng),
    };
    let mut mon = ExpansionMonitor::new();
    let ecfg = ExpandConfig::symmetric(BitSpec::int(BITS), TERMS);
    for _ in 0..4 {
        mon.observe(&Tensor::randn(&[32, DIN], 1.0, &mut rng), &ecfg).expect("monitor");
    }
    let ctl = Arc::new(TermController::new(QosConfig::new(TERMS)));
    ctl.calibrate(&mon);
    let rec = Arc::new(TraceRecorder::default());
    let pool =
        WorkerPool::new(TERMS, mlp_basis_factory_with(&w, BITS, TERMS, BiasPlacement::FirstTerm));
    let coord = Arc::new(Coordinator::new(
        BatcherConfig::uniform(16, 500, 256),
        ExpansionScheduler::new(pool).with_controller(ctl).with_recorder(rec),
    ));
    let handle = serve_tcp("127.0.0.1:0", coord.clone()).expect("bind");
    let addr = handle.addr;

    // mixed-tier traffic: 4 concurrent clients × 25 requests, server
    // assigning trace ids (wire id 0) and echoing them back
    let clients: Vec<_> = (0..4u64)
        .map(|c| {
            std::thread::spawn(move || {
                let mut rng = Rng::seed(900 + c);
                for i in 0..25usize {
                    let tier = Tier::ALL[(c as usize + i) % Tier::ALL.len()];
                    let x = Tensor::randn(&[8, DIN], 1.0, &mut rng);
                    let (_, id) = client_infer_traced(addr, &x, tier, 0).expect("request");
                    assert_ne!(id, 0, "server must assign a trace id");
                }
            })
        })
        .collect();
    for c in clients {
        c.join().unwrap();
    }

    let metrics = client_metrics(addr).expect("metrics scrape");
    let trace = client_trace_json(addr).expect("trace dump");
    handle.stop();

    let expo_path = format!("{outdir}/exposition.txt");
    let trace_path = format!("{outdir}/trace.json");
    std::fs::write(&expo_path, &metrics).expect("write exposition");
    std::fs::write(&trace_path, &trace).expect("write trace");

    println!("per-tier completed series:");
    for line in metrics.lines().filter(|l| l.starts_with("fpxint_requests_completed_total{")) {
        println!("  {line}");
    }
    println!(
        "wrote {expo_path} ({} bytes) and {trace_path} ({} bytes)",
        metrics.len(),
        trace.len()
    );
    println!("open {trace_path} in Perfetto (ui.perfetto.dev) or chrome://tracing");
}
