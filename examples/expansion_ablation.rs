//! Figure 4b interactive: accuracy and max activation residual vs the
//! number of expansion terms, plus the §5.3 auto-stop rule and the §5.4
//! ensemble control.
//!
//!     cargo run --release --example expansion_ablation [--bits 4]

use fp_xint::baselines::IntEnsemble;
use fp_xint::datasets::{accuracy, SynthImg};
use fp_xint::models::{quantized, zoo};
use fp_xint::train::{train_classifier, TrainConfig};
use fp_xint::util::{cli::Args, logger, Table};
use fp_xint::xint::layer::LayerPolicy;
use fp_xint::xint::{BitSpec, ExpandConfig, ExpansionMonitor};

fn main() {
    logger::init(false);
    let mut args = Args::from_env();
    let bits: u32 = args.get_num("bits", 4);

    let data = SynthImg::standard(13);
    let mut model = zoo::mini_resnet_c(10, 41);
    let cfg = TrainConfig { steps: 300, batch: 32, lr: 0.05, log_every: 100 };
    println!("training {} ({} params)…", model.name, model.params());
    let report = train_classifier(&mut model, &data, &cfg);
    let val = data.batch(512, 2);
    println!("FP val acc {:.2}%", report.final_val_acc * 100.0);

    // Figure 4b: accuracy + max residual vs expansion count
    let mut monitor = ExpansionMonitor::new();
    let probe = data.batch(16, 3).x;
    let cfg_exp = ExpandConfig::activations(BitSpec::int(bits), 6);
    monitor.observe(&probe, &cfg_exp).expect("one config per monitor series");

    let mut t = Table::new(
        &format!("expansion count ablation (W{bits}A{bits})"),
        &["terms", "val acc", "max |x - recon(x)|"],
    );
    for terms in 1..=6 {
        let q = quantized::quantize_model(
            &model,
            LayerPolicy::new(bits, bits).with_terms(2.min(terms), terms),
        );
        let acc = accuracy(&q.forward(&val.x), &val.y);
        let diff = monitor.max_diff()[terms - 1];
        t.row_str(&[
            &terms.to_string(),
            &format!("{:.2}%", acc * 100.0),
            &format!("{diff:.2e}"),
        ]);
    }
    t.print();
    match monitor.optimal_terms(1e-4) {
        Some(n) => println!("§5.3 auto-stop rule (max diff < 1e-4): optimal terms = {n}"),
        None => println!("§5.3 auto-stop rule: not reached within 6 terms"),
    }

    // §5.4: ensemble of INT models ≠ series expansion
    let calib = data.batch(64, 4).x;
    let mut t2 = Table::new(
        "ensemble-of-INT vs series (relative output error vs FP)",
        &["members/terms", "ensemble", "series"],
    );
    for k in [2usize, 4, 6] {
        let (ens, ser) = IntEnsemble::new(k, 7).versus_series(&model, bits.min(3), &calib);
        t2.row_str(&[&k.to_string(), &format!("{ens:.4}"), &format!("{ser:.4}")]);
    }
    t2.print();
    println!("series error must fall with terms; ensemble error plateaus (§5.4).");
}
