//! Quickstart: train a small MLP, series-expand it to W4A4 (Theorem 1 +
//! Eq. 4), and compare accuracy against FP and a naive RTN baseline.
//!
//!     cargo run --release --example quickstart
//!
//! Expected: xINT W4A4 within ~1 point of FP while RTN W4A4 drops more.

use fp_xint::baselines::{PtqMethod, Rtn};
use fp_xint::datasets::{accuracy, SynthImg};
use fp_xint::models::{quantized, zoo};
use fp_xint::train::{train_classifier, TrainConfig};
use fp_xint::util::{logger, Table};
use fp_xint::xint::layer::LayerPolicy;

fn main() {
    logger::init(false);
    // 1. a "pretrained FP model": train an MLP on the synthetic image task
    let data = SynthImg::standard(7);
    let mut model = zoo::mlp(256, &[64], 10, 11);
    let cfg = TrainConfig { steps: 400, batch: 32, lr: 0.08, log_every: 100 };
    println!("training FP model ({} params)…", model.params());
    let report = train_classifier(&mut model, &data, &cfg);
    let val = data.batch(512, 2);
    println!("FP val accuracy: {:.2}%", report.final_val_acc * 100.0);

    // 2. PTQ via series expansion — no calibration set, no fine-tuning
    let policy = LayerPolicy::new(4, 4); // W4A4, k=2 weight / t=4 activation terms
    let q = quantized::quantize_model(&model, policy);
    let q_acc = accuracy(&q.forward(&val.x), &val.y);

    // 3. naive baseline for contrast
    let calib = data.batch(32, 3).x;
    let rtn = Rtn.quantize(&model, 4, 4, &calib);
    let rtn_acc = accuracy(&rtn.forward(&val.x), &val.y);

    let mut t = Table::new("quickstart — MLP W4A4", &["method", "val acc"]);
    t.row_str(&["Full Prec.", &format!("{:.2}%", report.final_val_acc * 100.0)]);
    t.row_str(&["RTN W4A4", &format!("{:.2}%", rtn_acc * 100.0)]);
    t.row_str(&["Ours (series) W4A4", &format!("{:.2}%", q_acc * 100.0)]);
    t.print();

    assert!(q_acc >= rtn_acc, "series expansion should not lose to RTN");
    println!("OK");
}
