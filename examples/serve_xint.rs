//! Serving-focused demo: router + dynamic batcher + parallel basis
//! workers + AbelianAdd AllReduce, with a latency histogram and a
//! batching-policy sweep (the trade-off the coordinator perf bench
//! quantifies).
//!
//!     cargo run --release --example serve_xint

use fp_xint::coordinator::{BatcherConfig, Coordinator, ExpansionScheduler, WorkerPool};
use fp_xint::datasets::{RequestTrace, SynthImg};
use fp_xint::models::zoo;
use fp_xint::serve::loadgen::run_trace;
use fp_xint::serve::workers::{mlp_basis_factory, MlpWeights};
use fp_xint::train::{train_classifier, TrainConfig};
use fp_xint::util::{logger, Table};
use std::sync::Arc;

fn build_weights() -> MlpWeights {
    let data = SynthImg::standard(5);
    let mut mlp = zoo::mlp(256, &[64], 10, 31);
    let cfg = TrainConfig { steps: 200, batch: 32, lr: 0.08, log_every: 1000 };
    train_classifier(&mut mlp, &data, &cfg);
    mlp.fold_bn();
    use fp_xint::models::Layer;
    let linears: Vec<_> = mlp
        .layers
        .iter()
        .filter_map(|l| match l {
            Layer::Linear(lin) => Some(lin),
            _ => None,
        })
        .collect();
    MlpWeights {
        w1: linears[0].w.clone(),
        b1: linears[0].b.clone().unwrap(),
        w2: linears[1].w.clone(),
        b2: linears[1].b.clone().unwrap(),
    }
}

fn main() {
    logger::init(false);
    let weights = build_weights();
    let terms = 4;

    let mut table = Table::new(
        "batching policy sweep (xINT basis workers, Poisson trace 200 rps)",
        &["max_batch", "max_wait", "thpt (rps)", "p50 (ms)", "p99 (ms)", "shed"],
    );
    for (max_batch, max_wait_us) in
        [(1usize, 10u64), (8, 500), (32, 1_000), (32, 5_000), (128, 10_000)]
    {
        let pool = WorkerPool::new(terms, mlp_basis_factory(&weights, 4, terms));
        let coord = Arc::new(Coordinator::new(
            BatcherConfig::uniform(max_batch, max_wait_us, 512),
            ExpansionScheduler::new(pool),
        ));
        let trace = RequestTrace::new(200.0, 77);
        let report = run_trace(&coord, &trace, 1.5, 256, 1.0);
        table.row_str(&[
            &max_batch.to_string(),
            &format!("{} µs", max_wait_us),
            &format!("{:.1}", report.throughput_rps),
            &format!("{:.2}", report.latency.p50 * 1e3),
            &format!("{:.2}", report.latency.p99 * 1e3),
            &report.shed.to_string(),
        ]);
    }
    table.print();

    // latency histogram for the balanced setting
    let pool = WorkerPool::new(terms, mlp_basis_factory(&weights, 4, terms));
    let coord = Arc::new(Coordinator::new(
        BatcherConfig::uniform(32, 1_000, 512),
        ExpansionScheduler::new(pool),
    ));
    let trace = RequestTrace::new(200.0, 78);
    let report = run_trace(&coord, &trace, 2.0, 256, 1.0);
    println!("\nlatency distribution ({} requests):", report.completed);
    let s = &report.latency;
    for (label, v) in
        [("min", s.min), ("p50", s.p50), ("p95", s.p95), ("p99", s.p99), ("max", s.max)]
    {
        let bar = "▇".repeat(((v * 1e3).min(60.0)) as usize + 1);
        println!("  {label:>4} {:>8.2} ms  {bar}", v * 1e3);
    }
    println!("\n{report}");
}
